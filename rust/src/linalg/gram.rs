//! Incrementally maintained Gram matrix `B = AᵀA` and its inverse
//! `N = B^{-1}` — the heart of Inverse Hessian Boosting (paper §4.4,
//! Theorem 4.9).
//!
//! OAVI appends one column `b = u(X)` to the evaluation matrix `A`
//! whenever a border term u joins `O`.  [`GramState::append`] performs the
//! O(ℓ²) block-inverse update of Theorem 4.9 (the O(mℓ) part — computing
//! `Aᵀb`/`bᵀb` — lives in the streaming backend, not here).  Under the
//! degree-batched panel flow the trailing entries of that `Aᵀb` vector
//! are served from the cached panel cross-Gram
//! (`backend::PanelStats::cross_at`) rather than a data pass: the append
//! consumes the same numbers either way, so the maintained `(B, N)` is
//! bitwise independent of how the driver batched the degree.  A failed
//! Schur guard signals numerical rank deficiency; callers recover with
//! [`GramState::rebuild_inverse`] / a store rebuild (Cholesky + jitter).

use crate::backend::store::ColumnStore;
use crate::error::{AviError, Result};
use crate::linalg::chol::Cholesky;
use crate::linalg::dense::Matrix;
use crate::linalg::dot;

/// Maintained `B = AᵀA`, `N = B^{-1}` for a growing evaluation matrix.
#[derive(Clone, Debug)]
pub struct GramState {
    b: Matrix,
    n_inv: Matrix,
    /// number of samples m (rows of A); used by MSE = residual/m.
    m: usize,
    /// Maintain `N = B^{-1}` on append?  Pure-solver OAVI modes (PCGAVI,
    /// BPCGAVI without IHB) disable this so they don't pay IHB's O(ℓ²)
    /// bookkeeping they never use.
    track_inverse: bool,
}

/// Relative tolerance on the Schur complement: s must exceed
/// `SCHUR_RTOL · bᵀb` for the update to be considered numerically sound.
const SCHUR_RTOL: f64 = 1e-12;

impl GramState {
    /// Start with A = the constant-1 column (OAVI Line 2: O = {𝟙}):
    /// B = [[m]], N = [[1/m]].
    pub fn new_ones(m: usize) -> Self {
        let mut b = Matrix::zeros(1, 1);
        b.set(0, 0, m as f64);
        let mut n = Matrix::zeros(1, 1);
        n.set(0, 0, 1.0 / m as f64);
        GramState { b, n_inv: n, m, track_inverse: true }
    }

    /// Like [`GramState::new_ones`] but without inverse maintenance.
    pub fn new_ones_b_only(m: usize) -> Self {
        let mut g = GramState::new_ones(m);
        g.track_inverse = false;
        g
    }

    /// Build from explicit evaluation columns (used by rebuilds and
    /// tests).  Delegates to the store path with one shard — identical
    /// arithmetic to a direct dense build.
    pub fn from_columns(cols: &[Vec<f64>]) -> Result<Self> {
        if cols.is_empty() {
            return Err(AviError::Linalg("from_columns: empty".into()));
        }
        Self::build_from_store(&ColumnStore::from_cols(cols, 1), None)
    }

    /// Build from a sharded column store (shard-order dot accumulation —
    /// deterministic per shard count, like the streaming backends).
    pub fn from_store(store: &ColumnStore) -> Result<Self> {
        Self::build_from_store(store, None)
    }

    /// Build from a store **plus** one trailing candidate column that has
    /// not been appended yet — the Schur-guard recovery path of the OAVI
    /// driver (rebuild with the rejected-as-dependent column included).
    pub fn from_store_with_candidate(store: &ColumnStore, cand: &[f64]) -> Result<Self> {
        Self::build_from_store(store, Some(cand))
    }

    fn build_from_store(store: &ColumnStore, cand: Option<&[f64]>) -> Result<Self> {
        let base = store.len();
        let ell = base + usize::from(cand.is_some());
        if ell == 0 {
            return Err(AviError::Linalg("from_store: empty".into()));
        }
        let m = store.rows();
        let mut b = Matrix::zeros(ell, ell);
        for i in 0..base {
            for j in i..base {
                let v = store.dot_cols(i, j);
                b.set(i, j, v);
                b.set(j, i, v);
            }
        }
        if let Some(c) = cand {
            debug_assert_eq!(c.len(), m);
            for i in 0..base {
                let v = store.dot_col_slice(i, c);
                b.set(i, base, v);
                b.set(base, i, v);
            }
            b.set(base, base, dot(c, c));
        }
        let (chol, _jitter) = Cholesky::new_with_jitter(&b, 1e-10 * b.max_abs().max(1.0))?;
        let n_inv = chol.inverse();
        Ok(GramState { b, n_inv, m, track_inverse: true })
    }

    /// Current ℓ (number of columns of A).
    #[inline]
    pub fn len(&self) -> usize {
        self.b.rows()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of samples m.
    #[inline]
    pub fn samples(&self) -> usize {
        self.m
    }

    /// Gram matrix `B = AᵀA`.
    #[inline]
    pub fn b(&self) -> &Matrix {
        &self.b
    }

    /// Inverse `N = (AᵀA)^{-1}`.
    #[inline]
    pub fn n_inv(&self) -> &Matrix {
        &self.n_inv
    }

    /// Closed-form IHB solution of OAVI Line 7 for candidate column stats
    /// `(Aᵀb, bᵀb)`: returns `(c, m·MSE)` with `c = −N Aᵀb` and
    /// `m·MSE = bᵀb + cᵀAᵀb` (optimal residual; clamped at 0).
    pub fn solve_closed_form(&self, atb: &[f64], btb: f64) -> (Vec<f64>, f64) {
        debug_assert_eq!(atb.len(), self.len());
        assert!(self.track_inverse, "solve_closed_form requires inverse tracking");
        let mut c = self.n_inv.matvec(atb);
        for ci in c.iter_mut() {
            *ci = -*ci;
        }
        let resid = (btb + dot(&c, atb)).max(0.0);
        (c, resid)
    }

    /// Theorem 4.9: append column b with precomputed `atb = Aᵀb`,
    /// `btb = bᵀb` in O(ℓ²).  Errors with [`AviError::SchurNotPositive`]
    /// when b is numerically in span(A).
    pub fn append(&mut self, atb: &[f64], btb: f64) -> Result<()> {
        let ell = self.len();
        debug_assert_eq!(atb.len(), ell);
        if btb <= 0.0 {
            return Err(AviError::SchurNotPositive(btb));
        }
        // grow B
        let mut b_new = Matrix::zeros(ell + 1, ell + 1);
        for i in 0..ell {
            b_new.row_mut(i)[..ell].copy_from_slice(&self.b.row(i)[..ell]);
            b_new.set(i, ell, atb[i]);
            b_new.set(ell, i, atb[i]);
        }
        b_new.set(ell, ell, btb);

        if !self.track_inverse {
            self.b = b_new;
            return Ok(());
        }

        // w = N Aᵀb;  s = bᵀb − bᵀA N Aᵀb  (Schur complement)
        let w = self.n_inv.matvec(atb);
        let s = btb - dot(atb, &w);
        if s <= SCHUR_RTOL * btb {
            return Err(AviError::SchurNotPositive(s));
        }
        let inv_s = 1.0 / s;

        // grow N via the block-inverse formulas (Appendix A):
        //   Ñ₁ = N + w wᵀ / s,   ñ₂ = −w / s,   ñ₃ = 1 / s
        let mut n_new = Matrix::zeros(ell + 1, ell + 1);
        for i in 0..ell {
            let wi = w[i];
            let src = self.n_inv.row(i);
            let dst = n_new.row_mut(i);
            for j in 0..ell {
                dst[j] = src[j] + wi * w[j] * inv_s;
            }
            dst[ell] = -wi * inv_s;
        }
        for j in 0..ell {
            n_new.set(ell, j, -w[j] * inv_s);
        }
        n_new.set(ell, ell, inv_s);

        self.b = b_new;
        self.n_inv = n_new;
        Ok(())
    }

    /// Rebuild `N` from the stored `B` via Cholesky with jitter
    /// escalation — the recovery path after numerical failure, and a
    /// periodic hygiene step for very long runs.
    pub fn rebuild_inverse(&mut self) -> Result<f64> {
        let (chol, jitter) =
            Cholesky::new_with_jitter(&self.b, 1e-10 * self.b.max_abs().max(1.0))?;
        self.n_inv = chol.inverse();
        self.track_inverse = true;
        Ok(jitter)
    }

    /// ‖B·N − I‖∞ — inverse drift diagnostic used by tests and the
    /// perf-pass hygiene checks.
    pub fn inverse_drift(&self) -> f64 {
        let prod = self.b.matmul(&self.n_inv).expect("square");
        let n = prod.rows();
        let mut worst = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let target = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((prod.get(i, j) - target).abs());
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{all_close, close, property};
    use crate::util::rng::Rng;

    fn random_cols(rng: &mut Rng, m: usize, ell: usize) -> Vec<Vec<f64>> {
        (0..ell)
            .map(|_| (0..m).map(|_| rng.uniform()).collect())
            .collect()
    }

    #[test]
    fn new_ones_matches_manual() {
        let g = GramState::new_ones(50);
        assert_eq!(g.len(), 1);
        assert_eq!(g.b().get(0, 0), 50.0);
        assert!((g.n_inv().get(0, 0) - 0.02).abs() < 1e-15);
    }

    #[test]
    fn append_matches_fresh_inverse() {
        property(24, |rng| {
            let m = 30 + rng.below(50);
            let ell = 1 + rng.below(6);
            let cols = random_cols(rng, m, ell);
            // incremental build
            let mut g = GramState::from_columns(&cols[..1]).map_err(|e| e.to_string())?;
            for c in &cols[1..] {
                let atb: Vec<f64> = (0..g.len())
                    .map(|j| dot(&cols[j], c))
                    .collect();
                g.append(&atb, dot(c, c)).map_err(|e| e.to_string())?;
            }
            // fresh build
            let fresh = GramState::from_columns(&cols).map_err(|e| e.to_string())?;
            all_close(g.b().data(), fresh.b().data(), 1e-9, "B")?;
            all_close(g.n_inv().data(), fresh.n_inv().data(), 1e-5, "N")?;
            close(g.inverse_drift(), 0.0, 1e-6, "drift")
        });
    }

    #[test]
    fn append_rejects_dependent_column() {
        let mut rng = Rng::new(5);
        let m = 40;
        let c0: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let c1: Vec<f64> = c0.iter().map(|v| 2.0 * v).collect(); // dependent
        let mut g = GramState::from_columns(std::slice::from_ref(&c0)).unwrap();
        let atb = vec![dot(&c0, &c1)];
        let err = g.append(&atb, dot(&c1, &c1)).unwrap_err();
        assert!(matches!(err, AviError::SchurNotPositive(_)), "{err}");
    }

    #[test]
    fn append_rejects_zero_column() {
        let mut g = GramState::new_ones(10);
        assert!(g.append(&[0.0], 0.0).is_err());
    }

    #[test]
    fn closed_form_solves_least_squares() {
        property(24, |rng| {
            let m = 50 + rng.below(50);
            let ell = 1 + rng.below(5);
            let cols = random_cols(rng, m, ell);
            let b_col: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
            let g = GramState::from_columns(&cols).map_err(|e| e.to_string())?;
            let atb: Vec<f64> = cols.iter().map(|c| dot(c, &b_col)).collect();
            let (c, resid) = g.solve_closed_form(&atb, dot(&b_col, &b_col));
            // residual r = A c + b must be orthogonal to the columns of A
            let mut r = b_col.clone();
            for (j, col) in cols.iter().enumerate() {
                for (ri, ci) in r.iter_mut().zip(col.iter()) {
                    *ri += c[j] * ci;
                }
            }
            for col in &cols {
                close(dot(col, &r), 0.0, 1e-5 * m as f64, "orthogonality")?;
            }
            close(resid, dot(&r, &r), 1e-6, "residual value")
        });
    }

    #[test]
    fn rebuild_fixes_drift() {
        let mut rng = Rng::new(11);
        let cols = random_cols(&mut rng, 60, 5);
        let mut g = GramState::from_columns(&cols).unwrap();
        // corrupt the inverse
        g.n_inv.set(0, 0, g.n_inv.get(0, 0) + 0.5);
        assert!(g.inverse_drift() > 1e-3);
        g.rebuild_inverse().unwrap();
        assert!(g.inverse_drift() < 1e-7);
    }

    #[test]
    fn samples_reported() {
        assert_eq!(GramState::new_ones(123).samples(), 123);
    }

    #[test]
    fn from_store_matches_from_columns() {
        let mut rng = Rng::new(17);
        let cols = random_cols(&mut rng, 50, 4);
        let dense = GramState::from_columns(&cols).unwrap();
        for k in [1usize, 3, 7] {
            let store = crate::backend::store::ColumnStore::from_cols(&cols, k);
            let g = GramState::from_store(&store).unwrap();
            // same Gram up to shard-order summation
            for (a, b) in g.b().data().iter().zip(dense.b().data().iter()) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b} (shards {k})");
            }
            assert_eq!(g.samples(), 50);
            let cand: Vec<f64> = (0..50).map(|_| rng.uniform()).collect();
            let gc = GramState::from_store_with_candidate(&store, &cand).unwrap();
            assert_eq!(gc.len(), 5);
            assert!(gc.inverse_drift() < 1e-6);
        }
    }
}

#[cfg(test)]
mod tests_b_only {
    use super::*;
    use crate::linalg::dot;
    use crate::util::rng::Rng;

    #[test]
    fn b_only_mode_grows_b_without_inverse() {
        let mut rng = Rng::new(42);
        let m = 30;
        let ones = vec![1.0; m];
        let c1: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let mut g = GramState::new_ones_b_only(m);
        let atb = vec![dot(&ones, &c1)];
        g.append(&atb, dot(&c1, &c1)).unwrap();
        assert_eq!(g.len(), 2);
        assert_eq!(g.b().get(0, 1), atb[0]);
        // enabling tracking later via rebuild works
        g.rebuild_inverse().unwrap();
        assert!(g.inverse_drift() < 1e-8);
        let (_, resid) = g.solve_closed_form(&[0.0, 0.0], 1.0);
        assert!((resid - 1.0).abs() < 1e-12);
    }
}

//! Row-major dense matrix.

use crate::error::{AviError, Result};
use crate::linalg::dot;

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// From a flat row-major buffer.
    pub fn from_flat(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(AviError::Linalg(format!(
                "from_flat: {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// From nested rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        if rows.is_empty() {
            return Ok(Matrix::zeros(0, 0));
        }
        let cols = rows[0].len();
        if rows.iter().any(|r| r.len() != cols) {
            return Err(AviError::Linalg("from_rows: ragged rows".into()));
        }
        let data = rows.iter().flat_map(|r| r.iter().copied()).collect();
        Ok(Matrix { rows: rows.len(), cols, data })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column j.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Flat data access.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// y = A x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// y = Aᵀ x
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for (yj, aij) in y.iter_mut().zip(row.iter()) {
                *yj += xi * aij;
            }
        }
        y
    }

    /// C = A B (ikj loop order for cache friendliness).
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.rows {
            return Err(AviError::Linalg(format!(
                "matmul: {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut c = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let crow = c.row_mut(i);
                for (cij, bkj) in crow.iter_mut().zip(brow.iter()) {
                    *cij += aik * bkj;
                }
            }
        }
        Ok(c)
    }

    /// B = Aᵀ A (symmetric Gram).
    pub fn gram(&self) -> Matrix {
        let mut g = Matrix::zeros(self.cols, self.cols);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..self.cols {
                let ai = row[i];
                if ai == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for (j, aj) in row.iter().enumerate().skip(i) {
                    grow[j] += ai * aj;
                }
            }
        }
        // mirror the upper triangle
        for i in 0..self.cols {
            for j in 0..i {
                let v = g.get(j, i);
                g.set(i, j, v);
            }
        }
        g
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Frobenius norm of (self − other).
    pub fn diff_fro(&self, other: &Matrix) -> f64 {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> Matrix {
        Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]).unwrap()
    }

    #[test]
    fn basic_accessors() {
        let m = a();
        assert_eq!((m.rows(), m.cols()), (3, 2));
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(m.col(1), vec![2.0, 4.0, 6.0]);
        assert_eq!(m.row(2), &[5.0, 6.0]);
    }

    #[test]
    fn matvec_and_transpose() {
        let m = a();
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(m.t_matvec(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
        assert_eq!(m.transpose().matvec(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
    }

    #[test]
    fn matmul_matches_manual() {
        let m = a();
        let b = Matrix::from_rows(&[vec![1.0, 0.0, 2.0], vec![0.0, 1.0, 2.0]]).unwrap();
        let c = m.matmul(&b).unwrap();
        assert_eq!(c.row(0), &[1.0, 2.0, 6.0]);
        assert_eq!(c.row(2), &[5.0, 6.0, 22.0]);
    }

    #[test]
    fn matmul_dim_mismatch_errors() {
        assert!(a().matmul(&a()).is_err());
    }

    #[test]
    fn gram_is_ata() {
        let m = a();
        let g = m.gram();
        let ata = m.transpose().matmul(&m).unwrap();
        assert!(g.diff_fro(&ata) < 1e-12);
        // symmetry
        assert_eq!(g.get(0, 1), g.get(1, 0));
    }

    #[test]
    fn eye_and_zeros() {
        let i = Matrix::eye(3);
        assert_eq!(i.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
        assert_eq!(Matrix::zeros(2, 2).max_abs(), 0.0);
    }

    #[test]
    fn from_flat_validates() {
        assert!(Matrix::from_flat(2, 2, vec![1.0; 3]).is_err());
        assert!(Matrix::from_flat(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn from_rows_rejects_ragged() {
        assert!(Matrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]).is_err());
    }
}

//! Symmetric eigendecomposition (cyclic Jacobi) + power iteration.
//!
//! Consumers:
//! * ABM — smallest eigenpair of the bordered Gram matrix per border term
//!   (the paper's §6.1 "SVD of AᵀA" modification of Limbeck's ABM).
//! * VCA — full eigendecomposition of the projected candidate Gram.
//! * Solvers — λ_max/λ_min estimates for AGD step sizes and strong
//!   convexity.

use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;

/// Eigendecomposition result: `a = V diag(λ) Vᵀ`, eigenvalues ascending.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Column j of `vectors` is the eigenvector for `values[j]`.
    pub vectors: Matrix,
}

/// Cyclic Jacobi eigenvalue algorithm for symmetric matrices.
///
/// Converges quadratically; `max_sweeps` bounds the worst case.  For the
/// ℓ ≤ few-hundred Gram matrices in this codebase a handful of sweeps
/// reaches ~1e-12 off-diagonal mass.
pub fn sym_eig(a: &Matrix, max_sweeps: usize) -> Result<SymEig> {
    if a.rows() != a.cols() {
        return Err(AviError::Linalg("sym_eig: non-square".into()));
    }
    let n = a.rows();
    if n == 0 {
        return Ok(SymEig { values: vec![], vectors: Matrix::zeros(0, 0) });
    }
    let mut m = a.clone();
    let mut v = Matrix::eye(n);

    let off = |m: &Matrix| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                s += m.get(i, j) * m.get(i, j);
            }
        }
        s
    };
    let scale = a.max_abs().max(1e-300);
    let tol = (1e-14 * scale) * (1e-14 * scale) * (n * n) as f64;

    for _ in 0..max_sweeps {
        if off(&m) <= tol {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m.get(p, q);
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // rotate rows/cols p and q of m
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                // rotate eigenvector columns
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }

    // extract + sort ascending
    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
    idx.sort_by(|&i, &j| diag[i].total_cmp(&diag[j]));
    let values: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (new_j, &old_j) in idx.iter().enumerate() {
        for i in 0..n {
            vectors.set(i, new_j, v.get(i, old_j));
        }
    }
    Ok(SymEig { values, vectors })
}

/// Smallest eigenpair convenience (value, vector).
pub fn smallest_eigenpair(a: &Matrix) -> Result<(f64, Vec<f64>)> {
    let e = sym_eig(a, 30)?;
    Ok((e.values[0], e.vectors.col(0)))
}

/// Largest eigenvalue via power iteration (cheap; used for AGD's L).
pub fn lambda_max(a: &Matrix, iters: usize) -> f64 {
    let n = a.rows();
    if n == 0 {
        return 0.0;
    }
    // deterministic start with all-ones + small index perturbation to avoid
    // orthogonality to the principal eigenvector
    let mut x: Vec<f64> = (0..n).map(|i| 1.0 + 1e-3 * (i as f64)).collect();
    let mut lam = 0.0;
    for _ in 0..iters {
        let y = a.matvec(&x);
        let norm = crate::linalg::norm2(&y);
        if norm <= 1e-300 {
            return 0.0;
        }
        lam = crate::linalg::dot(&x, &y) / crate::linalg::dot(&x, &x);
        for (xi, yi) in x.iter_mut().zip(y.iter()) {
            *xi = yi / norm;
        }
    }
    lam.abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{close, property};
    use crate::util::rng::Rng;

    fn random_sym(rng: &mut Rng, n: usize) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    #[test]
    fn eig_of_diagonal() {
        let a = Matrix::from_rows(&[
            vec![3.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ])
        .unwrap();
        let e = sym_eig(&a, 30).unwrap();
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eig_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]).unwrap();
        let e = sym_eig(&a, 30).unwrap();
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
        // eigenvector for λ=1 is ±(1,-1)/√2
        let v0 = e.vectors.col(0);
        assert!((v0[0] + v0[1]).abs() < 1e-10);
    }

    #[test]
    fn property_reconstruction() {
        property(16, |rng| {
            let n = rng.below(7) + 1;
            let a = random_sym(rng, n);
            let e = sym_eig(&a, 40).map_err(|e| e.to_string())?;
            // A ≈ V Λ Vᵀ
            let mut recon = Matrix::zeros(n, n);
            for k in 0..n {
                let vk = e.vectors.col(k);
                for i in 0..n {
                    for j in 0..n {
                        let v = recon.get(i, j) + e.values[k] * vk[i] * vk[j];
                        recon.set(i, j, v);
                    }
                }
            }
            close(recon.diff_fro(&a), 0.0, 1e-8, "reconstruction")?;
            // eigenvalues ascending
            for w in e.values.windows(2) {
                if w[0] > w[1] + 1e-12 {
                    return Err(format!("not ascending: {:?}", e.values));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_orthonormal_vectors() {
        property(16, |rng| {
            let n = rng.below(6) + 2;
            let a = random_sym(rng, n);
            let e = sym_eig(&a, 40).map_err(|e| e.to_string())?;
            let vtv = e.vectors.transpose().matmul(&e.vectors).unwrap();
            close(vtv.diff_fro(&Matrix::eye(n)), 0.0, 1e-8, "VᵀV = I")
        });
    }

    #[test]
    fn lambda_max_matches_jacobi() {
        property(12, |rng| {
            let n = rng.below(6) + 2;
            let raw = random_sym(rng, n);
            let a = raw.matmul(&raw).unwrap(); // PSD so power iteration is clean
            let e = sym_eig(&a, 40).map_err(|e| e.to_string())?;
            let lmax = e.values[n - 1];
            close(lambda_max(&a, 200), lmax, 1e-4, "λ_max")
        });
    }

    #[test]
    fn smallest_eigenpair_residual() {
        let mut rng = Rng::new(9);
        let raw = random_sym(&mut rng, 5);
        let a = raw.matmul(&raw).unwrap();
        let (lam, v) = smallest_eigenpair(&a).unwrap();
        let av = a.matvec(&v);
        for i in 0..5 {
            assert!((av[i] - lam * v[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn empty_matrix_ok() {
        let e = sym_eig(&Matrix::zeros(0, 0), 5).unwrap();
        assert!(e.values.is_empty());
    }
}

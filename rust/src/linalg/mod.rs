//! Dense linear algebra substrate (BLAS-free, f64).
//!
//! OAVI's oracle works on Gram matrices `B = AᵀA ∈ R^{ℓ×ℓ}` with ℓ ≤ a few
//! hundred, plus streaming O(m·ℓ) products against the evaluation matrix.
//! The small-ℓ factorization side stays straightforward cache-friendly
//! loops with numerically defensive factorizations; the streaming O(m·ℓ)
//! side has an explicit SIMD-shaped kernel layer in [`simd`]: wide-lane
//! dot bricks (`dotN` — 4 or 8 columns sharing one pass over the
//! right-hand column) and carried-lane row tiling, both written as
//! unrolled f64x4-lane loops the compiler lowers to vector code.
//!
//! [`dot`] below is the **bitwise anchor** of that whole kernel family:
//! its fixed schedule (four lane accumulators over the `n/4` chunks,
//! lane combine `(s0+s1)+(s2+s3)`, sequential `n%4` tail) is reproduced
//! per output entry by every exact kernel in `backend/store.rs`, so
//! blocking, lane width, and row tiling change wall-clock only, never
//! result bits.  The one deliberate exception is the opt-in
//! mixed-precision path ([`simd::dot_fast`], `NumericsMode::Fast`),
//! which trades the bitwise contract for f32 tile accumulation under a
//! measured error budget.

pub mod chol;
pub mod dense;
pub mod eigen;
pub mod gram;
pub mod simd;

pub use chol::Cholesky;
pub use dense::Matrix;
pub use gram::GramState;

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: keeps the FP dependency chain short so
    // LLVM vectorizes; also slightly better numerics than naive.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    norm2_sq(x).sqrt()
}

/// ℓ1 norm.
#[inline]
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// max |x_i|.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |acc, v| acc.max(v.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..23).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..23).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_works() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn norms() {
        let v = vec![3.0, -4.0];
        assert_eq!(norm2(&v), 5.0);
        assert_eq!(norm1(&v), 7.0);
        assert_eq!(norm_inf(&v), 4.0);
        assert_eq!(norm2_sq(&v), 25.0);
    }
}

//! avi-scale CLI — the L3 leader entrypoint.
//!
//! Every generator method goes through the estimator layer
//! ([`avi_scale::estimator::EstimatorConfig`]): `--method` selects any
//! estimator by name and the rest of the command is method-agnostic —
//! fit, pipeline, save/load (all estimators persist, VCA included), and
//! serve behave identically for OAVI variants, ABM, and VCA.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! avi-scale datasets                      # Table 2: the dataset registry
//! avi-scale fit      [opts]               # fit one OAVI/ABM/VCA model per class
//! avi-scale pipeline [opts]               # full Algorithm-2 train/test run
//! avi-scale serve    [opts]               # batched transform service demo
//! avi-scale bound    [opts]               # Theorem 4.3 bound vs empirical
//! ```
//!
//! Common options: `--dataset <name>` `--method <name>` `--psi <f>`
//! `--scale <f>` `--seed <u64>` `--backend native|xla` `--ordering
//! pearson|reverse|native` `--workers <n>`.

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use avi_scale::backend::{ComputeBackend, NativeBackend};
use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::coordinator::service::{latency_percentiles, BatchPolicy, TransformService};
use avi_scale::data::{load_registry_dataset, REGISTRY};
use avi_scale::error::Result;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::oavi::OaviConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::{
    fit_transformer, fit_transformer_pooled, train_pipeline_pooled, train_pipeline_with_backend,
    PipelineConfig,
};
use avi_scale::runtime::{PjrtRuntime, XlaBackend};
use avi_scale::svm::linear::LinearSvmConfig;
use avi_scale::util::sci;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, opts)) = parse(&args) else {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    };
    let run = match cmd.as_str() {
        "datasets" => cmd_datasets(&opts),
        "fit" => cmd_fit(&opts),
        "pipeline" => cmd_pipeline(&opts),
        "predict" => cmd_predict(&opts),
        "serve" => cmd_serve(&opts),
        "bound" => cmd_bound(&opts),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            return ExitCode::from(2);
        }
    };
    match run {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
avi-scale — Approximate Vanishing Ideal computations at scale

USAGE: avi-scale <command> [--key value]...

COMMANDS:
  datasets    print the Table-2 dataset registry
  fit         fit generator models per class; print |G|+|O|, degree, SPAR
  pipeline    Algorithm-2 train/test run with a 60/40 split
              (--save <path> persists the trained pipeline as JSON)
  predict     load a saved pipeline (--model <path>) and evaluate it on a
              dataset's test split
  serve       batched transform service demo (latency/throughput)
  bound       Theorem 4.3 bound vs empirical |G|+|O|

OPTIONS:
  --dataset <bank|credit|htru|seeds|skin|spam|synthetic>   (default synthetic)
  --method  <cgavi-ihb|agdavi-ihb|bpcgavi-wihb|bpcgavi|pcgavi|cgavi|abm|vca>
  --psi <f64>            vanishing parameter        (default 0.005)
  --scale <f64>          dataset size multiplier    (default 0.05)
  --seed <u64>           RNG seed                   (default 42)
  --backend <native|xla|sharded>  compute backend   (default native: the
                         sequential reference, bit-identical everywhere)
  --workers <n>          size of the one persistent worker pool the whole
                         command shares: per-class fit / grid-point jobs
                         (outer axis) and ShardedBackend shard kernels
                         (inner axis) split this budget.  n>1 opts into
                         the pooled data plane (as does --backend sharded,
                         which without a count sizes the pool to the
                         machine: available parallelism - 1)
  --shards <n>           DEPRECATED alias for --workers (the old intra-fit
                         knob; --workers wins when both are given)
  --ordering <pearson|reverse|native>               (default pearson)
  --requests <n>         serve demo request count   (default 2000)
";

fn parse(args: &[String]) -> Option<(String, HashMap<String, String>)> {
    let cmd = args.first()?.clone();
    let mut opts = HashMap::new();
    let mut i = 1;
    while i < args.len() {
        let k = args[i].strip_prefix("--")?.to_string();
        let v = args.get(i + 1)?.clone();
        opts.insert(k, v);
        i += 2;
    }
    Some((cmd, opts))
}

fn opt_f64(opts: &HashMap<String, String>, key: &str, default: f64) -> f64 {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn opt_u64(opts: &HashMap<String, String>, key: &str, default: u64) -> u64 {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn opt_usize(opts: &HashMap<String, String>, key: &str, default: usize) -> usize {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn estimator_for(opts: &HashMap<String, String>, psi: f64) -> Result<EstimatorConfig> {
    let name = opts.get("method").map(|s| s.as_str()).unwrap_or("cgavi-ihb");
    EstimatorConfig::parse(name, psi)
}

fn ordering_for(name: &str) -> FeatureOrdering {
    match name {
        "reverse" => FeatureOrdering::ReversePearson,
        "native" => FeatureOrdering::Native,
        _ => FeatureOrdering::Pearson,
    }
}

/// The one persistent pool a command shares across both parallelism
/// levels.  `--workers N` sizes it; the old `--shards` knob survives as
/// a deprecated alias.
fn pool_for(opts: &HashMap<String, String>) -> ThreadPool {
    let workers = opt_usize(opts, "workers", 0);
    let legacy = opt_usize(opts, "shards", 0);
    let n = if workers > 0 {
        workers
    } else {
        if legacy > 0 {
            eprintln!("note: --shards is deprecated; use --workers {legacy}");
        }
        legacy
    };
    if n == 0 {
        ThreadPool::default_size()
    } else {
        ThreadPool::new(n)
    }
}

fn use_xla(opts: &HashMap<String, String>) -> bool {
    opts.get("backend").map(|s| s.as_str()) == Some("xla")
}

/// Whether the user opted into the parallel data plane.  The default
/// stays the sequential `NativeBackend` reference: its results are
/// bit-identical on every machine, whereas sharded results are
/// deterministic only *per shard count* (which tracks the worker
/// budget).  Parallelism must be an explicit choice, exactly as in the
/// pre-pool CLI.
fn parallel_requested(opts: &HashMap<String, String>) -> bool {
    opts.get("backend").map(|s| s.as_str()) == Some("sharded")
        || opt_usize(opts, "workers", 0) > 1
        || opt_usize(opts, "shards", 0) > 1
}

fn xla_backend(opts: &HashMap<String, String>) -> Result<Box<dyn ComputeBackend>> {
    if opt_usize(opts, "workers", 0) > 0 || opt_usize(opts, "shards", 0) > 0 {
        eprintln!(
            "note: --workers/--shards are ignored with --backend xla \
             (PJRT handles are thread-pinned; the XLA path runs sequentially)"
        );
    }
    let rt = Arc::new(PjrtRuntime::load_default()?);
    Ok(Box::new(XlaBackend::new(rt)))
}

fn load(opts: &HashMap<String, String>) -> Result<avi_scale::data::Dataset> {
    let name = opts.get("dataset").map(|s| s.as_str()).unwrap_or("synthetic");
    let scale = opt_f64(opts, "scale", 0.05);
    let seed = opt_u64(opts, "seed", 42);
    load_registry_dataset(name, scale, seed)
}

fn cmd_datasets(_opts: &HashMap<String, String>) -> Result<()> {
    println!(
        "{:<11} {:>9} {:>9} {:>8}   (Table 2; simulated — DESIGN.md §5)",
        "dataset", "#samples", "#features", "classes"
    );
    for name in REGISTRY {
        let ds = load_registry_dataset(name, 0.01, 0)?;
        let full_m: usize = match *name {
            "bank" => 1372,
            "credit" => 30_000,
            "htru" => 17_898,
            "seeds" => 210,
            "skin" => 245_057,
            "spam" => 4_601,
            _ => 2_000_000,
        };
        println!("{:<11} {:>9} {:>9} {:>8}", name, full_m, ds.n_features(), ds.n_classes);
    }
    Ok(())
}

fn cmd_fit(opts: &HashMap<String, String>) -> Result<()> {
    let ds = load(opts)?;
    let psi = opt_f64(opts, "psi", 0.005);
    let estimator = estimator_for(opts, psi)?;
    let ordering = ordering_for(opts.get("ordering").map(|s| s.as_str()).unwrap_or("pearson"));
    let perm = avi_scale::ordering::order_features(&ds.x, ordering);
    let ordered = ds.permute_features(&perm);
    let t0 = std::time::Instant::now();
    let (transformer, backend_name) = if use_xla(opts) {
        let backend = xla_backend(opts)?;
        let est = estimator.build();
        (fit_transformer(est.as_ref(), &ordered, backend.as_ref())?, backend.name().to_string())
    } else if parallel_requested(opts) {
        // two-level: per-class fits (outer) × shard kernels (inner) over
        // the one shared pool
        let pool = pool_for(opts);
        (
            fit_transformer_pooled(&estimator, &ordered, &pool.handle())?,
            format!("pooled({} workers)", pool.workers()),
        )
    } else {
        // default: the sequential reference — bit-identical everywhere
        let est = estimator.build();
        (fit_transformer(est.as_ref(), &ordered, &NativeBackend)?, "native".to_string())
    };
    let secs = t0.elapsed().as_secs_f64();
    println!("method    = {}", transformer.method_name);
    println!(
        "dataset   = {} (m={}, n={}, k={})",
        ds.name,
        ds.len(),
        ds.n_features(),
        ds.n_classes
    );
    println!("backend   = {backend_name}");
    println!("fit time  = {}s", sci(secs));
    let wall: f64 = transformer.per_class.iter().map(|c| c.report().wall_secs).sum();
    println!("fit wall  = {}s (Σ per-class FitReport)", sci(wall));
    println!("|G|+|O|   = {}", transformer.total_size());
    println!("|G|       = {}", transformer.n_generators());
    println!("avg deg   = {:.2}", transformer.avg_degree());
    println!("SPAR      = {:.2}", transformer.sparsity());
    Ok(())
}

fn cmd_pipeline(opts: &HashMap<String, String>) -> Result<()> {
    let ds = load(opts)?;
    let psi = opt_f64(opts, "psi", 0.005);
    let estimator = estimator_for(opts, psi)?;
    let ordering = ordering_for(opts.get("ordering").map(|s| s.as_str()).unwrap_or("pearson"));
    let split = avi_scale::data::splits::train_test_split(&ds, 0.6, opt_u64(opts, "seed", 42));
    let cfg = PipelineConfig { estimator, svm: LinearSvmConfig::default(), ordering };
    let t0 = std::time::Instant::now();
    let model = if use_xla(opts) {
        let backend = xla_backend(opts)?;
        train_pipeline_with_backend(&cfg, &split.train, backend.as_ref())?
    } else if parallel_requested(opts) {
        let pool = pool_for(opts);
        train_pipeline_pooled(&cfg, &split.train, &pool)?
    } else {
        avi_scale::pipeline::train_pipeline(&cfg, &split.train)?
    };
    let train_secs = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let err = model.error_on(&split.test);
    let test_secs = t1.elapsed().as_secs_f64();
    println!("method      = {}", model.transformer.method_name);
    println!(
        "dataset     = {} (train {}, test {})",
        ds.name,
        split.train.len(),
        split.test.len()
    );
    println!("train time  = {}s", sci(train_secs));
    println!("test time   = {}s", sci(test_secs));
    println!("test error  = {:.2}%", err * 100.0);
    println!("|G|+|O|     = {}", model.transformer.total_size());
    if let Some(path) = opts.get("save") {
        avi_scale::estimator::persist::save(&model, std::path::Path::new(path))?;
        println!("saved       = {path}");
    }
    Ok(())
}

fn cmd_predict(opts: &HashMap<String, String>) -> Result<()> {
    let path = opts
        .get("model")
        .ok_or_else(|| avi_scale::AviError::Config("predict needs --model <path>".into()))?;
    let model = avi_scale::estimator::persist::load(std::path::Path::new(path))?;
    let ds = load(opts)?;
    let split = avi_scale::data::splits::train_test_split(&ds, 0.6, opt_u64(opts, "seed", 42));
    let t = std::time::Instant::now();
    let err = model.error_on(&split.test);
    println!("model       = {path} ({})", model.transformer.method_name);
    println!("dataset     = {} (test {})", ds.name, split.test.len());
    println!("test error  = {:.2}%", err * 100.0);
    println!("test time   = {}s", sci(t.elapsed().as_secs_f64()));
    Ok(())
}

fn cmd_serve(opts: &HashMap<String, String>) -> Result<()> {
    let ds = load(opts)?;
    let psi = opt_f64(opts, "psi", 0.005);
    let estimator = estimator_for(opts, psi)?;
    let split = avi_scale::data::splits::train_test_split(&ds, 0.6, opt_u64(opts, "seed", 42));
    let cfg = PipelineConfig {
        estimator,
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    // `_pool` keeps the shared workers alive for the service's lifetime
    // (dropped, and joined, after `svc.shutdown()` at the end of the fn)
    let (svc, _pool) = if use_xla(opts) {
        let backend = xla_backend(opts)?;
        let model = Arc::new(train_pipeline_with_backend(&cfg, &split.train, backend.as_ref())?);
        (TransformService::start(model, BatchPolicy::default()), None)
    } else if parallel_requested(opts) {
        // serving draws its shard workers from the same pool that trained
        let pool = pool_for(opts);
        let model = Arc::new(train_pipeline_pooled(&cfg, &split.train, &pool)?);
        let svc = TransformService::start_pooled(
            model,
            BatchPolicy::default(),
            pool.handle(),
            pool.workers(),
        );
        (svc, Some(pool))
    } else {
        let model = Arc::new(avi_scale::pipeline::train_pipeline(&cfg, &split.train)?);
        (TransformService::start(model, BatchPolicy::default()), None)
    };
    let n_req = opt_usize(opts, "requests", 2000).min(split.test.len().max(1) * 50);
    let rows: Vec<Vec<f64>> = (0..n_req)
        .map(|i| split.test.x.row(i % split.test.len()).to_vec())
        .collect();
    let t0 = std::time::Instant::now();
    let responses = svc.predict_many(rows)?;
    let wall = t0.elapsed().as_secs_f64();
    let lat_us: Vec<f64> = responses.iter().map(|r| r.latency.as_secs_f64() * 1e6).collect();
    let (p50, p95, p99) = latency_percentiles(lat_us);
    println!("requests    = {n_req}");
    println!("throughput  = {:.0} req/s", n_req as f64 / wall);
    println!("latency p50 = {p50:.0}us  p95 = {p95:.0}us  p99 = {p99:.0}us");
    println!(
        "batches     = {} (max batch {})",
        svc.metrics.batches.load(std::sync::atomic::Ordering::Relaxed),
        svc.metrics.max_batch.load(std::sync::atomic::Ordering::Relaxed)
    );
    svc.shutdown();
    Ok(())
}

fn cmd_bound(opts: &HashMap<String, String>) -> Result<()> {
    let psi = opt_f64(opts, "psi", 0.005);
    let ds = load(opts)?;
    let cfg = OaviConfig::cgavi_ihb(psi);
    println!(
        "Theorem 4.3: D = {}, bound C(D+n, D) = {:.3e}",
        cfg.theorem_degree(),
        cfg.size_bound(ds.n_features())
    );
    let pool = pool_for(opts);
    let sizes: Vec<usize> = pool.map(&(0..ds.n_classes).collect::<Vec<_>>(), |&k| {
        let xk = ds.class_matrix(k);
        avi_scale::oavi::Oavi::new(cfg).fit(&xk).map(|m| m.total_size()).unwrap_or(0)
    });
    for (k, s) in sizes.iter().enumerate() {
        println!("class {k}: empirical |G|+|O| = {s}");
    }
    Ok(())
}

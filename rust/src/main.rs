//! avi-scale CLI — the L3 leader entrypoint.
//!
//! Every generator method goes through the estimator layer
//! ([`avi_scale::estimator::EstimatorConfig`]): `--method` selects any
//! estimator by name and the rest of the command is method-agnostic —
//! fit, pipeline, save/load (all estimators persist, VCA included), and
//! serve behave identically for OAVI variants, ABM, and VCA.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! avi-scale dataset <action> [opts]       # out-of-core data plane:
//!                                         #   ingest | inspect | stats | split | list
//! avi-scale model   <action> [opts]       # binary model artifacts:
//!                                         #   pack | unpack | inspect | push |
//!                                         #   pull | activate | query
//! avi-scale fit      [opts]               # fit one OAVI/ABM/VCA model per class
//! avi-scale pipeline [opts]               # full Algorithm-2 train/test run
//! avi-scale serve    [opts]               # batched transform service demo,
//!                                         #   or a TCP front door via --listen
//! avi-scale bound    [opts]               # Theorem 4.3 bound vs empirical
//! ```
//!
//! Common options: `--dataset <name>` `--data <dir>` `--method <name>`
//! `--psi <f>` `--scale <f>` `--seed <u64>` `--backend native|xla`
//! `--ordering pearson|reverse|native` `--workers <n>`
//! `--store mem|mmap` `--mem-budget-mb <n>`.
//!
//! `datasets` survives as an alias for `dataset list`.

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use avi_scale::artifact::{self, ArtifactStore};
use avi_scale::backend::{ComputeBackend, NativeBackend, StoreMode};
use avi_scale::coordinator::frontdoor::{
    FrontDoor, FrontDoorConfig, ModelControl, RateLimit, DEFAULT_MAX_RETAINED,
};
use avi_scale::coordinator::wire::WireClient;
use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::coordinator::registry::{namespaced, parse_spec, ModelRegistry};
use avi_scale::coordinator::router::ModelRouter;
use avi_scale::coordinator::service::{
    latency_percentiles, ServeConfig, ServeRequest, DEFAULT_QUEUE_CAPACITY,
};
use avi_scale::data::{load_registry_dataset, REGISTRY};
use avi_scale::backend::NumericsMode;
use avi_scale::error::Result;
use avi_scale::estimator::{EstimatorBuilder, EstimatorConfig};
use avi_scale::oavi::OaviConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::{
    fit_transformer, fit_transformer_pooled, train_pipeline_pooled, train_pipeline_with_backend,
    PipelineConfig,
};
use avi_scale::runtime::{PjrtRuntime, XlaBackend};
use avi_scale::storage::{
    ingest_csv, verify_segments, DatasetManifest, IngestOptions, DEFAULT_ROWS_PER_SHARD,
};
use avi_scale::svm::linear::LinearSvmConfig;
use avi_scale::util::sci;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(first) = args.first() else {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    };
    // `dataset <action>` / `model <action>` take one positional action
    // before the --key value pairs; every other command is options-only
    let (cmd, rest) = if first == "dataset" {
        let action = args.get(1).map(|s| s.as_str()).unwrap_or("list");
        (format!("dataset {action}"), &args[2.min(args.len())..])
    } else if first == "model" {
        let action = args.get(1).map(|s| s.as_str()).unwrap_or("help");
        (format!("model {action}"), &args[2.min(args.len())..])
    } else {
        (first.clone(), &args[1..])
    };
    let Some(opts) = parse_opts(rest) else {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    };
    let run = match cmd.as_str() {
        // `datasets` is the pre-dataset-family alias for `dataset list`
        "datasets" | "dataset list" => cmd_dataset_list(&opts),
        "dataset ingest" => cmd_dataset_ingest(&opts),
        "dataset inspect" => cmd_dataset_inspect(&opts),
        "dataset stats" => cmd_dataset_stats(&opts),
        "dataset split" => cmd_dataset_split(&opts),
        "model pack" => cmd_model_pack(&opts),
        "model unpack" => cmd_model_unpack(&opts),
        "model inspect" => cmd_model_inspect(&opts),
        "model push" => cmd_model_push(&opts),
        "model pull" => cmd_model_pull(&opts),
        "model activate" => cmd_model_activate(&opts),
        "model query" => cmd_model_query(&opts),
        "fit" => cmd_fit(&opts),
        "pipeline" => cmd_pipeline(&opts),
        "predict" => cmd_predict(&opts),
        "serve" => cmd_serve(&opts),
        "bound" => cmd_bound(&opts),
        "help" | "--help" | "-h" | "dataset help" | "model help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            return ExitCode::from(2);
        }
    };
    match run {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
avi-scale — Approximate Vanishing Ideal computations at scale

USAGE: avi-scale <command> [--key value]...

COMMANDS:
  dataset     out-of-core data plane (manifest-backed shard directories):
                dataset list                    the Table-2 registry (alias: datasets)
                dataset ingest  --csv <f> --out <dir> [--name <s>]
                                [--rows-per-shard <n>]
                                stream a CSV into checksummed shard segments
                                (single pass; peak memory = one row-group)
                dataset inspect --data <dir>    manifest + per-segment checksums
                dataset stats   --data <dir>    streaming per-column min/max/mean
                dataset split   --data <dir> --out-train <dir> --out-test <dir>
                                [--test-frac <f>] [--seed <n>]
  model       binary model artifacts (AVIB codec — docs/model-artifacts.md):
                model pack     --model <envelope> --out <f>
                               re-encode a saved pipeline (JSON or binary)
                               as a compact binary artifact; floats are
                               preserved bitwise in both directions
                model unpack   --model <artifact> --out <f>
                               back to the JSON envelope
                model inspect  --model <f> | --store <dir>
                               codec, sizes, FNV-1a-64 checksum; with
                               --store, the checksummed manifest listing
                model push     --addr <ip:port> --key <k> --version <v>
                               --model <f> [--force true]
                               upload to a live server's artifact store
                               (refused on checksum mismatch or when the
                               version exists with different contents)
                model pull     --addr <ip:port> --key <k> [--version <v>]
                               --out <f>   download the (checksum-verified)
                               artifact; latest version when omitted
                model activate --addr <ip:port> --key <k> --version <v>
                               hot-swap the route to a stored version
                model query    --addr <ip:port> --route <k> --row <csv>
                               one prediction; scores print bitwise
                               (shortest-round-trip floats)
  fit         fit generator models per class; print |G|+|O|, degree, SPAR
  pipeline    Algorithm-2 train/test run with a 60/40 split
              (--save <path> persists the trained pipeline as JSON)
  predict     load a saved pipeline (--model <path>) and evaluate it on a
              dataset's test split
  serve       serving control plane: front door → registry → router →
              service.  Without --model it trains one pipeline from
              --dataset and serves it as default@v1; with --model it
              loads saved pipelines into the registry and routes traffic
              across them.  By default it drives an in-process demo and
              prints latency/throughput plus the RouterReport JSON;
              --listen <addr> binds the framed TCP wire protocol instead
              and serves until a Shutdown frame arrives.
  bound       Theorem 4.3 bound vs empirical |G|+|O|

OPTIONS:
  --dataset <bank|credit|htru|seeds|skin|spam|synthetic>   (default synthetic)
  --data <dir>           load an ingested dataset directory (from `dataset
                         ingest`) instead of the registry; segments are
                         checksum-verified before use
  --store <mem|mmap>     OAVI working-store backing (default mem).  mmap
                         spills evaluation columns to checksummed on-disk
                         segments under an LRU resident-byte budget; exact
                         results are bitwise identical to mem for any
                         fixed shard count
  --mem-budget-mb <n>    resident-byte budget for mmap stores and --data
                         loading (default 256)
  --method  <cgavi-ihb|agdavi-ihb|bpcgavi-wihb|bpcgavi|pcgavi|cgavi|abm|vca>
  --psi <f64>            vanishing parameter        (default 0.005)
  --scale <f64>          dataset size multiplier    (default 0.05)
  --seed <u64>           RNG seed                   (default 42)
  --backend <native|xla|sharded>  compute backend   (default native: the
                         sequential reference, bit-identical everywhere)
  --workers <n>          size of the one persistent worker pool the whole
                         command shares: per-class fit / grid-point jobs
                         (outer axis) and ShardedBackend shard kernels
                         (inner axis) split this budget.  n>1 opts into
                         the pooled data plane (as does --backend sharded,
                         which without a count sizes the pool to the
                         machine: available parallelism - 1)
  --shards <n>           DEPRECATED alias for --workers (the old intra-fit
                         knob; --workers wins when both are given).
                         NOTE the PR-3 semantics drift: the value now
                         sizes the ONE shared worker pool and is
                         budget-split across per-class fit jobs
                         (outer × inner ≤ workers), so e.g. --shards 4 on
                         a 2-class fit gives each class inner=2 — a
                         different store shard count (hence different
                         bits) than the old per-fit ShardedBackend(4)
  --ordering <pearson|reverse|native>               (default pearson)
  --numerics <exact|fast>  panel-kernel numerics    (default exact).
                         'fast' (OAVI family only) opts into the
                         f32-accumulated panel kernels; the fit measures
                         max |Δ| vs the f64 reference on a sampled Gram
                         sub-block, fails if it exceeds the budget, and
                         reports both in the FitReport JSON
                         (fast_max_abs_err / fast_err_budget)
  --fast-tol <f64>       fast-mode error tolerance, relative to the
                         largest sampled exact entry (default 1e-3)

SERVE OPTIONS:
  --requests <n>         request count              (default 2000)
  --model <specs>        comma-separated key[@version]=path registry
                         entries (paths from `pipeline --save`); traffic
                         goes to the --ab key, else the first key
  --ab <key:v1=70,v2=30> weighted A/B split across versions of one key
                         (deterministic assignment, seeded by --seed)
  --shadow <key:ver>     mirror the key's traffic to one extra version
                         (replies discarded, latency recorded)
  --queue <n>            bounded per-route queue; overflow rejects
                         synchronously (default: fits the demo traffic,
                         max(requests, 1024))
  --deadline-ms <n>      per-request queue deadline (default none)
  --listen <addr>        serve over TCP instead of the in-process demo:
                         bind the framed wire protocol (AVIW frames,
                         JSON payloads — docs/wire-protocol.md) on
                         <addr> (port 0 picks an ephemeral port, printed
                         as `listening = ip:port`), then block until a
                         Shutdown frame arrives; prints wire counters
                         plus the RouterReport JSON on exit.  Network
                         scores are bitwise identical to in-process
                         serving.
  --tenant <name>        prefix every registry key as `name/key`
                         (per-tenant namespacing; clients route to the
                         prefixed key)
  --rate-limit <r>       per-route token bucket: r tokens/sec (0 = never
                         refill — whatever --burst grants is all a route
                         ever gets); over-limit requests get a typed
                         `rate_limited` rejection (default: unlimited)
  --burst <b>            token-bucket burst capacity (default max(r, 1))
  --read-timeout-ms <n>  per-connection read deadline; a silent peer is
                         reaped, never waited on forever (default 5000)
  --write-timeout-ms <n> per-connection write deadline (default 5000)
  --max-frame-kb <n>     frame payload cap; larger frames are rejected
                         from the header alone with a typed `oversized`
                         error (default 1024)
  --max-conns <n>        handler-thread cap; connections beyond it get a
                         typed `busy` error frame (default 256)
  --artifact-dir <dir>   enable the model control plane on --listen: open
                         (or create) a checksummed artifact store there
                         and accept PushModel / PullModel / ActivateModel
                         frames; without it control frames get a typed
                         `push_disabled` rejection
  --max-versions <n>     retained versions per key in the store/registry
                         (default 4; the latest and every live route stay
                         pinned regardless)
";

fn parse_opts(args: &[String]) -> Option<HashMap<String, String>> {
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i].strip_prefix("--")?.to_string();
        let v = args.get(i + 1)?.clone();
        opts.insert(k, v);
        i += 2;
    }
    Some(opts)
}

fn opt_f64(opts: &HashMap<String, String>, key: &str, default: f64) -> f64 {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn opt_u64(opts: &HashMap<String, String>, key: &str, default: u64) -> u64 {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn opt_usize(opts: &HashMap<String, String>, key: &str, default: usize) -> usize {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `--store mem|mmap` (+ `--mem-budget-mb`) → a [`StoreMode`].
fn store_mode_for(opts: &HashMap<String, String>) -> Result<Option<StoreMode>> {
    let Some(mode) = opts.get("store") else {
        return Ok(None);
    };
    let budget_mb = opt_usize(opts, "mem-budget-mb", 256);
    match mode.as_str() {
        "mem" => Ok(Some(StoreMode::Memory)),
        "mmap" => Ok(Some(StoreMode::spill_mb(budget_mb))),
        other => Err(avi_scale::AviError::Config(format!(
            "--store must be mem|mmap, got '{other}'"
        ))),
    }
}

fn estimator_for(opts: &HashMap<String, String>, psi: f64) -> Result<EstimatorConfig> {
    let name = opts.get("method").map(|s| s.as_str()).unwrap_or("cgavi-ihb");
    let mut builder = EstimatorBuilder::new(name).psi(psi);
    if let Some(mode) = store_mode_for(opts)? {
        builder = builder.store(mode);
    }
    if let Some(mode) = opts.get("numerics") {
        builder = builder.numerics(match mode.as_str() {
            "exact" => NumericsMode::Exact,
            "fast" => NumericsMode::Fast,
            other => {
                return Err(avi_scale::AviError::Config(format!(
                    "--numerics must be exact|fast, got '{other}'"
                )))
            }
        });
    }
    if let Some(tol) = opts.get("fast-tol") {
        let tol: f64 = tol.parse().map_err(|_| {
            avi_scale::AviError::Config(format!("--fast-tol '{tol}': not a number"))
        })?;
        builder = builder.fast_tol(tol);
    }
    builder.build()
}

fn ordering_for(name: &str) -> FeatureOrdering {
    match name {
        "reverse" => FeatureOrdering::ReversePearson,
        "native" => FeatureOrdering::Native,
        _ => FeatureOrdering::Pearson,
    }
}

/// The one persistent pool a command shares across both parallelism
/// levels.  `--workers N` sizes it; the old `--shards` knob survives as
/// a deprecated alias.
fn pool_for(opts: &HashMap<String, String>) -> ThreadPool {
    let workers = opt_usize(opts, "workers", 0);
    let legacy = opt_usize(opts, "shards", 0);
    let n = if workers > 0 {
        workers
    } else {
        if legacy > 0 {
            eprintln!("note: --shards is deprecated; use --workers {legacy}");
        }
        legacy
    };
    if n == 0 {
        ThreadPool::default_size()
    } else {
        ThreadPool::new(n)
    }
}

fn use_xla(opts: &HashMap<String, String>) -> bool {
    opts.get("backend").map(|s| s.as_str()) == Some("xla")
}

/// Whether the user opted into the parallel data plane.  The default
/// stays the sequential `NativeBackend` reference: its results are
/// bit-identical on every machine, whereas sharded results are
/// deterministic only *per shard count* (which tracks the worker
/// budget).  Parallelism must be an explicit choice, exactly as in the
/// pre-pool CLI.
fn parallel_requested(opts: &HashMap<String, String>) -> bool {
    opts.get("backend").map(|s| s.as_str()) == Some("sharded")
        || opt_usize(opts, "workers", 0) > 1
        || opt_usize(opts, "shards", 0) > 1
}

fn xla_backend(opts: &HashMap<String, String>) -> Result<Box<dyn ComputeBackend>> {
    if opt_usize(opts, "workers", 0) > 0 || opt_usize(opts, "shards", 0) > 0 {
        eprintln!(
            "note: --workers/--shards are ignored with --backend xla \
             (PJRT handles are thread-pinned; the XLA path runs sequentially)"
        );
    }
    let rt = Arc::new(PjrtRuntime::load_default()?);
    Ok(Box::new(XlaBackend::new(rt)))
}

fn load(opts: &HashMap<String, String>) -> Result<avi_scale::data::Dataset> {
    // an ingested dataset directory wins over the simulated registry
    if let Some(dir) = opts.get("data") {
        return avi_scale::storage::open_dataset(
            std::path::Path::new(dir),
            opt_usize(opts, "mem-budget-mb", 0) << 20,
        );
    }
    let name = opts.get("dataset").map(|s| s.as_str()).unwrap_or("synthetic");
    let scale = opt_f64(opts, "scale", 0.05);
    let seed = opt_u64(opts, "seed", 42);
    load_registry_dataset(name, scale, seed)
}

/// `--data <dir>` as a path, required by the dataset actions.
fn data_dir(opts: &HashMap<String, String>) -> Result<std::path::PathBuf> {
    opts.get("data").map(std::path::PathBuf::from).ok_or_else(|| {
        avi_scale::AviError::Config("this action needs --data <dir> (from `dataset ingest`)".into())
    })
}

fn cmd_dataset_ingest(opts: &HashMap<String, String>) -> Result<()> {
    let csv = opts
        .get("csv")
        .ok_or_else(|| avi_scale::AviError::Config("dataset ingest needs --csv <path>".into()))?;
    let out = opts
        .get("out")
        .ok_or_else(|| avi_scale::AviError::Config("dataset ingest needs --out <dir>".into()))?;
    let ingest_opts = IngestOptions {
        name: opts.get("name").cloned().unwrap_or_else(|| "ingested".into()),
        rows_per_shard: opt_usize(opts, "rows-per-shard", DEFAULT_ROWS_PER_SHARD),
    };
    let t0 = std::time::Instant::now();
    let man = ingest_csv(std::path::Path::new(csv), std::path::Path::new(out), &ingest_opts)?;
    println!("ingested    = {} ({} rows x {} cols)", man.name, man.rows, man.cols);
    println!("segments    = {} (<= {} rows each)", man.segments.len(), ingest_opts.rows_per_shard);
    println!("labels      = {:?}", man.labels_uniq);
    println!("out         = {out}");
    println!("ingest time = {}s", sci(t0.elapsed().as_secs_f64()));
    Ok(())
}

fn cmd_dataset_inspect(opts: &HashMap<String, String>) -> Result<()> {
    let dir = data_dir(opts)?;
    let man = DatasetManifest::load(&dir)?;
    verify_segments(&dir, &man)?;
    println!("name     = {}", man.name);
    println!("rows     = {}", man.rows);
    println!("cols     = {} ({} features + label)", man.cols, man.n_features());
    println!("labels   = {:?}", man.labels_uniq);
    println!("segments = {}", man.segments.len());
    for seg in &man.segments {
        println!(
            "  {:<14} rows={:<8} bytes={:<12} fnv1a64={:016x}",
            seg.file, seg.rows, seg.bytes, seg.checksum
        );
    }
    println!("verify   = ok (every segment checksum matches the manifest)");
    Ok(())
}

fn cmd_dataset_stats(opts: &HashMap<String, String>) -> Result<()> {
    let dir = data_dir(opts)?;
    let budget = opt_usize(opts, "mem-budget-mb", 0) << 20;
    let (man, store) = avi_scale::storage::open_store(&dir, budget)?;
    let stats = avi_scale::storage::column_stats(&store);
    println!("dataset  = {} ({} rows, {} shards)", man.name, man.rows, store.n_shards());
    for (j, st) in stats.iter().enumerate() {
        let tag = if j + 1 == man.cols { "label" } else { "feat " };
        println!(
            "col {j:<4} [{tag}] min={} max={} mean={}",
            sci(st.min),
            sci(st.max),
            sci(st.mean)
        );
    }
    if let Some(c) = store.backing_counters() {
        println!(
            "store    = {} loads, peak resident {} B (budget {} B)",
            c.loads, c.peak_resident_bytes, c.budget_bytes
        );
    }
    Ok(())
}

fn cmd_dataset_split(opts: &HashMap<String, String>) -> Result<()> {
    let dir = data_dir(opts)?;
    let out_train = opts.get("out-train").ok_or_else(|| {
        avi_scale::AviError::Config("dataset split needs --out-train <dir>".into())
    })?;
    let out_test = opts.get("out-test").ok_or_else(|| {
        avi_scale::AviError::Config("dataset split needs --out-test <dir>".into())
    })?;
    let frac = opt_f64(opts, "test-frac", 0.4);
    let seed = opt_u64(opts, "seed", 42);
    let (tr, te) = avi_scale::storage::split_dataset(
        &dir,
        std::path::Path::new(out_train),
        std::path::Path::new(out_test),
        frac,
        seed,
    )?;
    println!("train       = {} ({} rows) -> {out_train}", tr.name, tr.rows);
    println!("test        = {} ({} rows) -> {out_test}", te.name, te.rows);
    Ok(())
}

fn cmd_dataset_list(_opts: &HashMap<String, String>) -> Result<()> {
    println!(
        "{:<11} {:>9} {:>9} {:>8}   (Table 2; simulated — DESIGN.md §5)",
        "dataset", "#samples", "#features", "classes"
    );
    for name in REGISTRY {
        let ds = load_registry_dataset(name, 0.01, 0)?;
        let full_m: usize = match *name {
            "bank" => 1372,
            "credit" => 30_000,
            "htru" => 17_898,
            "seeds" => 210,
            "skin" => 245_057,
            "spam" => 4_601,
            _ => 2_000_000,
        };
        println!("{:<11} {:>9} {:>9} {:>8}", name, full_m, ds.n_features(), ds.n_classes);
    }
    Ok(())
}

fn cmd_fit(opts: &HashMap<String, String>) -> Result<()> {
    let ds = load(opts)?;
    let psi = opt_f64(opts, "psi", 0.005);
    let estimator = estimator_for(opts, psi)?;
    let ordering = ordering_for(opts.get("ordering").map(|s| s.as_str()).unwrap_or("pearson"));
    let perm = avi_scale::ordering::order_features(&ds.x, ordering);
    let ordered = ds.permute_features(&perm);
    let t0 = std::time::Instant::now();
    let (transformer, backend_name) = if use_xla(opts) {
        let backend = xla_backend(opts)?;
        let est = estimator.build();
        (fit_transformer(est.as_ref(), &ordered, backend.as_ref())?, backend.name().to_string())
    } else if parallel_requested(opts) {
        // two-level: per-class fits (outer) × shard kernels (inner) over
        // the one shared pool
        let pool = pool_for(opts);
        (
            fit_transformer_pooled(&estimator, &ordered, &pool.handle())?,
            format!("pooled({} workers)", pool.workers()),
        )
    } else {
        // default: the sequential reference — bit-identical everywhere
        let est = estimator.build();
        (fit_transformer(est.as_ref(), &ordered, &NativeBackend)?, "native".to_string())
    };
    let secs = t0.elapsed().as_secs_f64();
    println!("method    = {}", transformer.method_name);
    println!(
        "dataset   = {} (m={}, n={}, k={})",
        ds.name,
        ds.len(),
        ds.n_features(),
        ds.n_classes
    );
    println!("backend   = {backend_name}");
    println!("fit time  = {}s", sci(secs));
    let wall: f64 = transformer.per_class.iter().map(|c| c.report().wall_secs).sum();
    println!("fit wall  = {}s (Σ per-class FitReport)", sci(wall));
    println!("|G|+|O|   = {}", transformer.total_size());
    println!("|G|       = {}", transformer.n_generators());
    println!("avg deg   = {:.2}", transformer.avg_degree());
    println!("SPAR      = {:.2}", transformer.sparsity());
    let agg = transformer.aggregate_stats();
    println!(
        "panels    = {} passes / {} cols, cross-cache hits = {}, warm starts = {}",
        agg.panel_passes, agg.panel_cols, agg.cross_cache_hits, agg.warm_starts
    );
    for (k, c) in transformer.per_class.iter().enumerate() {
        println!("report[{k}] = {}", c.report().to_json());
    }
    Ok(())
}

fn cmd_pipeline(opts: &HashMap<String, String>) -> Result<()> {
    let ds = load(opts)?;
    let psi = opt_f64(opts, "psi", 0.005);
    let estimator = estimator_for(opts, psi)?;
    let ordering = ordering_for(opts.get("ordering").map(|s| s.as_str()).unwrap_or("pearson"));
    let split = avi_scale::data::splits::train_test_split(&ds, 0.6, opt_u64(opts, "seed", 42));
    let cfg = PipelineConfig { estimator, svm: LinearSvmConfig::default(), ordering };
    let t0 = std::time::Instant::now();
    let model = if use_xla(opts) {
        let backend = xla_backend(opts)?;
        train_pipeline_with_backend(&cfg, &split.train, backend.as_ref())?
    } else if parallel_requested(opts) {
        let pool = pool_for(opts);
        train_pipeline_pooled(&cfg, &split.train, &pool)?
    } else {
        avi_scale::pipeline::train_pipeline(&cfg, &split.train)?
    };
    let train_secs = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let err = model.error_on(&split.test);
    let test_secs = t1.elapsed().as_secs_f64();
    println!("method      = {}", model.transformer.method_name);
    println!(
        "dataset     = {} (train {}, test {})",
        ds.name,
        split.train.len(),
        split.test.len()
    );
    println!("train time  = {}s", sci(train_secs));
    println!("test time   = {}s", sci(test_secs));
    println!("test error  = {:.2}%", err * 100.0);
    println!("|G|+|O|     = {}", model.transformer.total_size());
    let agg = model.transformer.aggregate_stats();
    println!(
        "panels      = {} passes / {} cols, cross-cache hits = {}, warm starts = {}",
        agg.panel_passes, agg.panel_cols, agg.cross_cache_hits, agg.warm_starts
    );
    if let Some(path) = opts.get("save") {
        avi_scale::estimator::persist::save(&model, std::path::Path::new(path))?;
        println!("saved       = {path}");
    }
    Ok(())
}

fn cmd_predict(opts: &HashMap<String, String>) -> Result<()> {
    let path = opts
        .get("model")
        .ok_or_else(|| avi_scale::AviError::Config("predict needs --model <path>".into()))?;
    let model = avi_scale::estimator::persist::load(std::path::Path::new(path))?;
    let ds = load(opts)?;
    let split = avi_scale::data::splits::train_test_split(&ds, 0.6, opt_u64(opts, "seed", 42));
    let t = std::time::Instant::now();
    let err = model.error_on(&split.test);
    println!("model       = {path} ({})", model.transformer.method_name);
    println!("dataset     = {} (test {})", ds.name, split.test.len());
    println!("test error  = {:.2}%", err * 100.0);
    println!("test time   = {}s", sci(t.elapsed().as_secs_f64()));
    Ok(())
}

// ---------------------------------------------------------------------
// model — binary artifact family (docs/model-artifacts.md)
// ---------------------------------------------------------------------

fn req<'a>(opts: &'a HashMap<String, String>, key: &str, what: &str) -> Result<&'a String> {
    opts.get(key)
        .ok_or_else(|| avi_scale::AviError::Config(format!("{what} needs --{key} <value>")))
}

fn opt_force(opts: &HashMap<String, String>) -> bool {
    opts.get("force").map(|v| v == "true" || v == "1").unwrap_or(false)
}

/// Re-encode a saved pipeline envelope (either codec) as a compact
/// binary artifact.  Floats survive bitwise in both directions.
fn cmd_model_pack(opts: &HashMap<String, String>) -> Result<()> {
    let src = req(opts, "model", "model pack")?;
    let out = req(opts, "out", "model pack")?;
    let bytes = std::fs::read(src)?;
    let model = avi_scale::estimator::persist::pipeline_from_bytes(&bytes)?;
    let packed = artifact::encode_pipeline(&model)?;
    std::fs::write(out, &packed)?;
    println!("packed   = {out}");
    println!(
        "source   = {src} ({})",
        if artifact::codec::is_binary(&bytes) { "binary" } else { "json" }
    );
    println!("bytes    = {} -> {}", bytes.len(), packed.len());
    println!("checksum = {:016x}", artifact::fnv64(&packed));
    Ok(())
}

/// Back to the JSON envelope (the two codecs are interchangeable behind
/// the persistence version gate).
fn cmd_model_unpack(opts: &HashMap<String, String>) -> Result<()> {
    let src = req(opts, "model", "model unpack")?;
    let out = req(opts, "out", "model unpack")?;
    let model = avi_scale::estimator::persist::load(std::path::Path::new(src))?;
    avi_scale::estimator::persist::save(&model, std::path::Path::new(out))?;
    println!("unpacked = {src} -> {out}");
    Ok(())
}

/// Codec, shape, and checksum of one artifact — or the manifest listing
/// of a whole store directory via `--store`.
fn cmd_model_inspect(opts: &HashMap<String, String>) -> Result<()> {
    if let Some(dir) = opts.get("store") {
        let store = ArtifactStore::open(dir)?;
        println!("store = {dir} ({} artifacts, checksums verified)", store.list().len());
        println!("{:<32} {:>10}  {:<16}  file", "key@version", "bytes", "checksum");
        for e in store.list() {
            println!(
                "{:<32} {:>10}  {:016x}  {}",
                format!("{}@{}", e.key, e.version),
                e.bytes,
                e.checksum,
                e.file
            );
        }
        return Ok(());
    }
    let src = req(opts, "model", "model inspect")?;
    let bytes = std::fs::read(src)?;
    let model = avi_scale::estimator::persist::pipeline_from_bytes(&bytes)?;
    println!("model    = {src}");
    println!(
        "codec    = {}",
        if artifact::codec::is_binary(&bytes) { "binary (AVIB)" } else { "json" }
    );
    println!("method   = {}", model.transformer.method_name);
    println!("classes  = {}", model.n_classes);
    println!("bytes    = {}", bytes.len());
    println!("checksum = {:016x}", artifact::fnv64(&bytes));
    Ok(())
}

/// Upload an artifact to a live server (`serve --listen --artifact-dir`).
fn cmd_model_push(opts: &HashMap<String, String>) -> Result<()> {
    let addr = req(opts, "addr", "model push")?;
    let key = req(opts, "key", "model push")?;
    let version = req(opts, "version", "model push")?;
    let src = req(opts, "model", "model push")?;
    let bytes = std::fs::read(src)?;
    let mut client = WireClient::connect(addr)?;
    let ack = client.push_model(key, version, &bytes, opt_force(opts))?.ack()?;
    println!(
        "pushed   = {}@{} ({} bytes, checksum {:016x})",
        ack.key, ack.version, ack.bytes, ack.checksum
    );
    Ok(())
}

/// Download the checksum-verified artifact for `key` (latest version
/// unless `--version` is given).
fn cmd_model_pull(opts: &HashMap<String, String>) -> Result<()> {
    let addr = req(opts, "addr", "model pull")?;
    let key = req(opts, "key", "model pull")?;
    let out = req(opts, "out", "model pull")?;
    let mut client = WireClient::connect(addr)?;
    let pulled = client
        .pull_model(key, opts.get("version").map(|s| s.as_str()))?
        .model()?;
    std::fs::write(out, &pulled.artifact)?;
    println!(
        "pulled   = {}@{} -> {out} ({} bytes, checksum {:016x})",
        pulled.key,
        pulled.version,
        pulled.artifact.len(),
        pulled.checksum
    );
    Ok(())
}

/// Hot-swap a route to a stored version on a live server.
fn cmd_model_activate(opts: &HashMap<String, String>) -> Result<()> {
    let addr = req(opts, "addr", "model activate")?;
    let key = req(opts, "key", "model activate")?;
    let version = req(opts, "version", "model activate")?;
    let mut client = WireClient::connect(addr)?;
    let ack = client.activate_model(key, version)?.ack()?;
    println!("active   = {}@{}", ack.key, ack.version);
    Ok(())
}

/// One prediction over the wire; scores print as shortest-round-trip
/// floats so two servers can be compared bitwise from the shell.
fn cmd_model_query(opts: &HashMap<String, String>) -> Result<()> {
    let addr = req(opts, "addr", "model query")?;
    let route = req(opts, "route", "model query")?;
    let row = req(opts, "row", "model query")?
        .split(',')
        .map(|t| {
            t.trim().parse::<f64>().map_err(|_| {
                avi_scale::AviError::Config(format!("--row: '{t}' is not a number"))
            })
        })
        .collect::<Result<Vec<f64>>>()?;
    let mut client = WireClient::connect(addr)?;
    let answer = client.request(route, &ServeRequest::row(row))?.answer()?;
    let p = answer
        .predictions
        .first()
        .ok_or_else(|| avi_scale::AviError::Net("empty prediction set".into()))?;
    println!("route  = {}@{}", answer.key, answer.version);
    println!("label  = {}", p.label);
    println!("scores = {:?}", p.scores);
    Ok(())
}

/// Parse `--ab key:v1=70,v2=30` into `(key, [(version, weight)])`.
fn parse_ab(spec: &str) -> Result<(String, Vec<(String, u32)>)> {
    let (key, arms_src) = spec
        .split_once(':')
        .ok_or_else(|| avi_scale::AviError::Config(format!("--ab '{spec}': expected key:v=w,…")))?;
    let mut arms = Vec::new();
    for part in arms_src.split(',') {
        let (version, weight) = part.split_once('=').ok_or_else(|| {
            avi_scale::AviError::Config(format!("--ab arm '{part}': expected version=weight"))
        })?;
        let weight: u32 = weight.parse().map_err(|_| {
            avi_scale::AviError::Config(format!("--ab arm '{part}': weight not a number"))
        })?;
        arms.push((version.to_string(), weight));
    }
    Ok((key.to_string(), arms))
}

fn cmd_serve(opts: &HashMap<String, String>) -> Result<()> {
    if opts.contains_key("shards") {
        eprintln!(
            "warning: --shards is deprecated; use --workers N.  Since the pooled \
             data plane (PR 3), the value sizes the ONE shared worker pool and is \
             budget-split across per-class fit jobs (outer × inner ≤ workers), so \
             e.g. --shards 4 on a 2-class fit gives each class inner=2 — a \
             different store shard count (hence different bits) than the old \
             per-fit ShardedBackend(4)."
        );
    }
    let seed = opt_u64(opts, "seed", 42);
    let ds = load(opts)?;
    let split = avi_scale::data::splits::train_test_split(&ds, 0.6, seed);

    // serve configuration: backend choice + queue bound, one surface.
    // The demo enqueues its whole request set before waiting, so unless
    // the user bounds the queue explicitly (--queue exercises admission
    // control), size it to hold the demo traffic.
    let n_req_hint = opt_usize(opts, "requests", 2000);
    let mut serve_cfg = ServeConfig::new().queue_capacity(
        opt_usize(opts, "queue", n_req_hint.max(DEFAULT_QUEUE_CAPACITY)),
    );
    // `_pool` keeps the shared workers alive for the router's lifetime
    // (dropped, and joined, after the services shut down on router drop)
    let mut _pool: Option<ThreadPool> = None;
    if !use_xla(opts) && parallel_requested(opts) {
        let pool = pool_for(opts);
        serve_cfg = serve_cfg.pooled(pool.handle(), pool.workers());
        _pool = Some(pool);
    }

    // registry: saved pipelines via --model, else train from the dataset.
    // --tenant prefixes every key (`tenant/key`): multi-tenancy is a
    // naming convention over plain registry keys, not a parallel lookup
    // path — see `registry::namespaced`.
    let tenant = opts.get("tenant").map(|s| s.as_str()).unwrap_or("");
    let mut registry = ModelRegistry::new();
    if let Some(specs) = opts.get("model") {
        for spec in specs.split(',') {
            let (kv, path) = spec.split_once('=').ok_or_else(|| {
                avi_scale::AviError::Config(format!(
                    "--model '{spec}': expected key[@version]=path"
                ))
            })?;
            let (key, version) = parse_spec(kv)?;
            let key = namespaced(tenant, &key);
            registry.load_path(&key, &version, std::path::Path::new(path))?;
            println!("loaded      = {key}@{version} from {path}");
        }
    } else {
        let psi = opt_f64(opts, "psi", 0.005);
        let estimator = estimator_for(opts, psi)?;
        let cfg = PipelineConfig {
            estimator,
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        let model = if use_xla(opts) {
            let backend = xla_backend(opts)?;
            Arc::new(train_pipeline_with_backend(&cfg, &split.train, backend.as_ref())?)
        } else if let Some(pool) = &_pool {
            // serving draws its shard workers from the same pool that trained
            Arc::new(train_pipeline_pooled(&cfg, &split.train, pool)?)
        } else {
            Arc::new(avi_scale::pipeline::train_pipeline(&cfg, &split.train)?)
        };
        registry.insert(namespaced(tenant, "default"), "v1", model)?;
    }

    // router: the --ab key gets its weighted split, every other key its
    // latest version (registering the A/B key twice would leave a
    // throwaway retired row in the report)
    let ab = opts.get("ab").map(|s| parse_ab(s)).transpose()?;
    let router = ModelRouter::new();
    for key in registry.keys() {
        if ab.as_ref().is_some_and(|(k, _)| *k == key) {
            continue;
        }
        if let Some((version, model)) = registry.latest(&key) {
            // adopt the transform plan compiled at registry insert so the
            // route is warmed before its first request
            let mut cfg = serve_cfg.clone();
            if let Some(plan) = registry.plan_for(&key, &version) {
                cfg = cfg.with_plan(plan);
            }
            router.register(key, version, model, cfg);
        }
    }
    let mut target_key = registry.keys().first().cloned().unwrap_or_default();
    if let Some((key, arms)) = ab {
        router.register_ab(&registry, &key, &arms, seed, &serve_cfg)?;
        println!(
            "ab split    = {key}: {}",
            arms.iter().map(|(v, w)| format!("{v}={w}")).collect::<Vec<_>>().join(",")
        );
        target_key = key;
    }
    if let Some(shadow) = opts.get("shadow") {
        let (key, version) = match shadow.split_once(':') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (target_key.clone(), shadow.clone()),
        };
        let model = registry.resolve(&key, &version)?;
        let mut cfg = serve_cfg.clone();
        if let Some(plan) = registry.plan_for(&key, &version) {
            cfg = cfg.with_plan(plan);
        }
        router.set_shadow(&key, &version, model, cfg)?;
        println!("shadow      = {key}:{version}");
    }

    // --listen: hand the configured router to the network front door and
    // block until a client sends a Shutdown frame (or the process is
    // killed).  The demo traffic loop below is the in-process
    // alternative; the two paths serve bitwise-identical scores.
    if let Some(addr) = opts.get("listen") {
        let rate_limit = opts
            .get("rate-limit")
            .map(|rate| {
                let per_sec: f64 = rate.parse().map_err(|_| {
                    avi_scale::AviError::Config(format!("--rate-limit '{rate}': not a number"))
                })?;
                Ok(RateLimit { per_sec, burst: opt_f64(opts, "burst", per_sec.max(1.0)) })
            })
            .transpose()?;
        // --artifact-dir arms the model control plane: the registry the
        // routes were built from becomes the conflict gate for pushes,
        // and activations hot-swap through this same router
        let model_control = match opts.get("artifact-dir") {
            Some(dir) => {
                let store = ArtifactStore::open(dir)?;
                let max_versions =
                    opt_usize(opts, "max-versions", DEFAULT_MAX_RETAINED);
                println!(
                    "artifacts = {dir} ({} stored, max {max_versions} versions/key)",
                    store.list().len()
                );
                Some(Arc::new(
                    ModelControl::new(registry, store, serve_cfg.clone())
                        .with_tenant(tenant)
                        .with_max_retained(max_versions),
                ))
            }
            None => None,
        };
        let fd_cfg = FrontDoorConfig {
            addr: addr.clone(),
            read_timeout: std::time::Duration::from_millis(opt_u64(
                opts,
                "read-timeout-ms",
                5_000,
            )),
            write_timeout: std::time::Duration::from_millis(opt_u64(
                opts,
                "write-timeout-ms",
                5_000,
            )),
            max_frame_bytes: opt_usize(opts, "max-frame-kb", 1024) << 10,
            rate_limit,
            max_connections: opt_usize(opts, "max-conns", 256),
            model_control,
        };
        let fd = FrontDoor::start(Arc::new(router), fd_cfg)?;
        // the e2e harness reads this line to learn the ephemeral port;
        // piped stdout is block-buffered, so flush explicitly
        println!("listening = {}", fd.local_addr());
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        fd.wait_shutdown();
        let report = fd.shutdown();
        let wire = report.wire.unwrap_or_default();
        println!("wire.connections    = {}", wire.connections);
        println!("wire.accepted       = {}", wire.accepted);
        println!("wire.rejected_limit = {}", wire.rejected_limit);
        println!("wire.rejected_route = {}", wire.rejected_route);
        println!("wire.timed_out      = {}", wire.timed_out);
        println!("wire.malformed      = {}", wire.malformed);
        println!("wire.oversized      = {}", wire.oversized);
        println!(
            "wire.model_ops      = {} push / {} pull / {} activate",
            wire.model_pushes, wire.model_pulls, wire.model_activations
        );
        println!("wire.bytes          = {} in / {} out", wire.bytes_in, wire.bytes_out);
        println!("{}", report.to_json());
        return Ok(());
    }

    // drive traffic from the dataset's test split
    let n_req = opt_usize(opts, "requests", 2000).min(split.test.len().max(1) * 50);
    let deadline_ms = opt_u64(opts, "deadline-ms", 0);
    let t0 = std::time::Instant::now();
    let pendings = (0..n_req)
        .map(|i| {
            let mut req = ServeRequest::row(split.test.x.row(i % split.test.len()).to_vec());
            if deadline_ms > 0 {
                req = req.with_deadline(std::time::Duration::from_millis(deadline_ms));
            }
            router.enqueue(&target_key, req)
        })
        .collect::<Result<Vec<_>>>()?;
    let mut lat_us: Vec<f64> = Vec::with_capacity(n_req);
    let mut by_version: HashMap<String, usize> = HashMap::new();
    let mut rejected = 0usize;
    for pending in pendings {
        match pending.wait() {
            avi_scale::coordinator::ServeReply::Answered(ans) => {
                lat_us.push((ans.queue_latency + ans.compute_latency).as_secs_f64() * 1e6);
                *by_version.entry(ans.model_version).or_default() += 1;
            }
            avi_scale::coordinator::ServeReply::Rejected(_) => rejected += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (p50, p95, p99) = latency_percentiles(lat_us);
    println!("requests    = {n_req} (route {target_key}, {rejected} rejected)");
    let mut versions: Vec<(String, usize)> = by_version.into_iter().collect();
    versions.sort();
    for (version, count) in versions {
        println!("served      = {version}: {count}");
    }
    println!("throughput  = {:.0} req/s", n_req as f64 / wall);
    println!("latency p50 = {p50:.0}us  p95 = {p95:.0}us  p99 = {p99:.0}us");
    let report = router.report();
    println!("router.total_requests = {}", report.total_requests);
    println!("router.total_rejected = {}", report.total_rejected);
    println!("{}", report.to_json());
    Ok(())
}

fn cmd_bound(opts: &HashMap<String, String>) -> Result<()> {
    let psi = opt_f64(opts, "psi", 0.005);
    let ds = load(opts)?;
    let cfg = OaviConfig::cgavi_ihb(psi);
    println!(
        "Theorem 4.3: D = {}, bound C(D+n, D) = {:.3e}",
        cfg.theorem_degree(),
        cfg.size_bound(ds.n_features())
    );
    let pool = pool_for(opts);
    let sizes: Vec<usize> = pool.map(&(0..ds.n_classes).collect::<Vec<_>>(), |&k| {
        let xk = ds.class_matrix(k);
        avi_scale::oavi::Oavi::new(cfg).fit(&xk).map(|m| m.total_size()).unwrap_or(0)
    });
    for (k, s) in sizes.iter().enumerate() {
        println!("class {k}: empirical |G|+|O| = {s}");
    }
    Ok(())
}

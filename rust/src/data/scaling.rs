//! Min-max feature scaling (paper §6.1: every dataset is scaled to [0,1]).
//!
//! In the pipeline the scaler is *fit on training data* and applied to
//! test data with clamping to [0,1] — out-of-range test values would break
//! the `X ⊆ [0,1]^n` assumption of Theorem 4.3.

use crate::linalg::dense::Matrix;

/// Per-feature (min, max) fitted on training data.
#[derive(Clone, Debug)]
pub struct MinMaxScaler {
    mins: Vec<f64>,
    maxs: Vec<f64>,
}

impl MinMaxScaler {
    /// Fit on the rows of `x`.
    pub fn fit(x: &Matrix) -> Self {
        let n = x.cols();
        let mut mins = vec![f64::INFINITY; n];
        let mut maxs = vec![f64::NEG_INFINITY; n];
        for i in 0..x.rows() {
            for j in 0..n {
                let v = x.get(i, j);
                mins[j] = mins[j].min(v);
                maxs[j] = maxs[j].max(v);
            }
        }
        // constant features scale to 0
        for j in 0..n {
            if !mins[j].is_finite() {
                mins[j] = 0.0;
                maxs[j] = 1.0;
            }
        }
        MinMaxScaler { mins, maxs }
    }

    /// Transform (clamped to [0,1]).
    pub fn transform(&self, x: &Matrix) -> Matrix {
        let mut out = x.clone();
        self.transform_in_place(&mut out);
        out
    }

    pub fn transform_in_place(&self, x: &mut Matrix) {
        let n = x.cols();
        assert_eq!(n, self.mins.len());
        for i in 0..x.rows() {
            for j in 0..n {
                let range = self.maxs[j] - self.mins[j];
                let v = if range > 0.0 {
                    (x.get(i, j) - self.mins[j]) / range
                } else {
                    0.0
                };
                x.set(i, j, v.clamp(0.0, 1.0));
            }
        }
    }
}

/// One-shot scaling of a full matrix (dataset generators).
pub fn minmax_scale_in_place(x: &mut Matrix) {
    let scaler = MinMaxScaler::fit(x);
    scaler.transform_in_place(x);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_to_unit_interval() {
        let mut x = Matrix::from_rows(&[vec![-2.0, 10.0], vec![0.0, 20.0], vec![2.0, 15.0]])
            .unwrap();
        minmax_scale_in_place(&mut x);
        assert_eq!(x.get(0, 0), 0.0);
        assert_eq!(x.get(2, 0), 1.0);
        assert_eq!(x.get(1, 1), 1.0);
        assert_eq!(x.get(0, 1), 0.0);
        assert!((x.get(2, 1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn test_data_is_clamped() {
        let train = Matrix::from_rows(&[vec![0.0], vec![10.0]]).unwrap();
        let scaler = MinMaxScaler::fit(&train);
        let test = Matrix::from_rows(&[vec![-5.0], vec![15.0], vec![5.0]]).unwrap();
        let t = scaler.transform(&test);
        assert_eq!(t.get(0, 0), 0.0);
        assert_eq!(t.get(1, 0), 1.0);
        assert_eq!(t.get(2, 0), 0.5);
    }

    #[test]
    fn constant_feature_maps_to_zero() {
        let train = Matrix::from_rows(&[vec![3.0], vec![3.0]]).unwrap();
        let scaler = MinMaxScaler::fit(&train);
        let t = scaler.transform(&train);
        assert_eq!(t.get(0, 0), 0.0);
        assert_eq!(t.get(1, 0), 0.0);
    }
}

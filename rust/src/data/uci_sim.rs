//! Simulated stand-ins for the paper's UCI datasets (Table 2).
//!
//! No network access is available in this environment, so each dataset is
//! replaced by a *seeded generator with the same shape* (m, n, #classes)
//! whose classes satisfy the paper's core modelling assumption: every
//! class lies near a low-dimensional **algebraic set** (the image of a
//! latent cube under class-specific quadratic polynomial maps), perturbed
//! by feature noise; dataset difficulty is controlled by label noise
//! calibrated to the paper's reported test errors (DESIGN.md §5).
//!
//! If real UCI CSVs are placed under `data/uci/<name>.csv` (label in the
//! last column), [`crate::data::csvio::load_csv_dataset`] can be used
//! instead; the pipeline code is agnostic.

use crate::data::scaling::minmax_scale_in_place;
use crate::data::Dataset;
use crate::error::Result;
use crate::linalg::dense::Matrix;
use crate::util::rng::Rng;

/// Configuration of one simulated dataset.
struct SimSpec {
    name: &'static str,
    n: usize,
    k: usize,
    /// latent dimension of each class variety
    latent: usize,
    /// feature noise σ
    noise: f64,
    /// label-flip probability (sets the Bayes-error floor ≈ paper error)
    label_noise: f64,
    /// structure seed: fixes the random varieties independently of the
    /// sampling seed so train/test share the same geometry
    structure_seed: u64,
}

/// Degree-2 polynomial map R^L → R: c0 + Σ ci t_i + Σ cij t_i t_j.
struct Quad {
    c0: f64,
    lin: Vec<f64>,
    quad: Vec<Vec<f64>>,
}

impl Quad {
    fn random(rng: &mut Rng, l: usize) -> Quad {
        Quad {
            c0: rng.uniform_in(-0.5, 0.5),
            lin: (0..l).map(|_| rng.uniform_in(-1.0, 1.0)).collect(),
            quad: (0..l)
                .map(|_| (0..l).map(|_| rng.uniform_in(-0.8, 0.8)).collect())
                .collect(),
        }
    }

    fn eval(&self, t: &[f64]) -> f64 {
        let mut v = self.c0;
        for (i, ti) in t.iter().enumerate() {
            v += self.lin[i] * ti;
            for (j, tj) in t.iter().enumerate() {
                v += self.quad[i][j] * ti * tj;
            }
        }
        v
    }
}

fn generate(spec: &SimSpec, m: usize, seed: u64) -> Result<Dataset> {
    // class-conditional quadratic feature maps (structure fixed by the
    // dataset's structure_seed, not the sampling seed)
    let mut srng = Rng::new(spec.structure_seed);
    let maps: Vec<Vec<Quad>> = (0..spec.k)
        .map(|_| (0..spec.n).map(|_| Quad::random(&mut srng, spec.latent)).collect())
        .collect();

    let mut rng = Rng::new(seed ^ spec.structure_seed.rotate_left(17));
    let mut x = Matrix::zeros(m, spec.n);
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        let true_class = i % spec.k;
        let t: Vec<f64> = (0..spec.latent).map(|_| rng.uniform()).collect();
        for j in 0..spec.n {
            let v = maps[true_class][j].eval(&t) + rng.normal_ms(0.0, spec.noise);
            x.set(i, j, v);
        }
        // label noise sets the irreducible error floor
        let label = if rng.uniform() < spec.label_noise {
            (true_class + 1 + rng.below(spec.k.max(2) - 1)) % spec.k
        } else {
            true_class
        };
        y.push(label);
    }
    minmax_scale_in_place(&mut x);
    // canonical shuffle so head(m') is class-balanced
    let mut idx: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut idx);
    let ds = Dataset::new(spec.name, x, y, spec.k)?;
    Ok(ds.subset(&idx))
}

/// banknote authentication: 1372×4, 2 classes, ≈0% error.
pub fn bank(m: usize, seed: u64) -> Result<Dataset> {
    generate(
        &SimSpec {
            name: "bank",
            n: 4,
            k: 2,
            latent: 2,
            noise: 0.01,
            label_noise: 0.0,
            structure_seed: 0xBA7C,
        },
        m,
        seed,
    )
}

/// default of credit cards: 30000×22, 2 classes, ≈18% error.
pub fn credit(m: usize, seed: u64) -> Result<Dataset> {
    generate(
        &SimSpec {
            name: "credit",
            n: 22,
            k: 2,
            latent: 4,
            noise: 0.08,
            label_noise: 0.175,
            structure_seed: 0xC4ED,
        },
        m,
        seed,
    )
}

/// HTRU2 pulsar candidates: 17898×8, 2 classes, ≈2% error.
pub fn htru(m: usize, seed: u64) -> Result<Dataset> {
    generate(
        &SimSpec {
            name: "htru",
            n: 8,
            k: 2,
            latent: 3,
            noise: 0.03,
            label_noise: 0.019,
            structure_seed: 0x47E0,
        },
        m,
        seed,
    )
}

/// seeds: 210×7, 3 classes, ≈4% error.
pub fn seeds(m: usize, seed: u64) -> Result<Dataset> {
    generate(
        &SimSpec {
            name: "seeds",
            n: 7,
            k: 3,
            latent: 2,
            noise: 0.04,
            label_noise: 0.035,
            structure_seed: 0x5EED,
        },
        m,
        seed,
    )
}

/// skin segmentation: 245057×3, 2 classes, ≈0.2% error.
pub fn skin(m: usize, seed: u64) -> Result<Dataset> {
    generate(
        &SimSpec {
            name: "skin",
            n: 3,
            k: 2,
            latent: 2,
            noise: 0.015,
            label_noise: 0.002,
            structure_seed: 0x5C17,
        },
        m,
        seed,
    )
}

/// spambase: 4601×57, 2 classes, ≈7% error.
pub fn spam(m: usize, seed: u64) -> Result<Dataset> {
    generate(
        &SimSpec {
            name: "spam",
            n: 57,
            k: 2,
            latent: 5,
            noise: 0.06,
            label_noise: 0.06,
            structure_seed: 0x59A3,
        },
        m,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_registry() {
        let cases: [(&str, fn(usize, u64) -> Result<Dataset>, usize, usize); 6] = [
            ("bank", bank, 4, 2),
            ("credit", credit, 22, 2),
            ("htru", htru, 8, 2),
            ("seeds", seeds, 7, 3),
            ("skin", skin, 3, 2),
            ("spam", spam, 57, 2),
        ];
        for (name, f, n, k) in cases {
            let ds = f(300, 1).unwrap();
            assert_eq!(ds.n_features(), n, "{name}");
            assert_eq!(ds.n_classes, k, "{name}");
            assert_eq!(ds.len(), 300, "{name}");
            for v in ds.x.data() {
                assert!((0.0..=1.0).contains(v), "{name}");
            }
            // roughly class-balanced
            for c in ds.class_counts() {
                assert!(c > 300 / (k * 2), "{name}: class count {c}");
            }
        }
    }

    #[test]
    fn structure_is_stable_across_sampling_seeds() {
        // different sampling seeds → different points, same varieties; a
        // weak proxy: per-feature means should agree across seeds well
        // beyond what fresh random geometry would give
        let a = bank(2000, 1).unwrap();
        let b = bank(2000, 2).unwrap();
        for j in 0..4 {
            let mean = |d: &Dataset| {
                (0..d.len()).map(|i| d.x.get(i, j)).sum::<f64>() / d.len() as f64
            };
            assert!((mean(&a) - mean(&b)).abs() < 0.05, "feature {j}");
        }
        assert_ne!(a.x.data()[..20], b.x.data()[..20]);
    }

    #[test]
    fn easy_dataset_is_linearly_less_mixed_than_hard() {
        // Fisher-style criterion on the first feature: bank (clean) should
        // show much larger class separation relative to noise than credit.
        let sep = |ds: &Dataset| {
            let mut sums = vec![0.0; ds.n_classes];
            let mut counts = vec![0usize; ds.n_classes];
            for i in 0..ds.len() {
                sums[ds.y[i]] += ds.x.get(i, 0);
                counts[ds.y[i]] += 1;
            }
            let mu: Vec<f64> =
                sums.iter().zip(&counts).map(|(s, &c)| s / c as f64).collect();
            (mu[0] - mu[1]).abs()
        };
        let easy = bank(3000, 5).unwrap();
        let hard = credit(3000, 5).unwrap();
        // not guaranteed feature-by-feature, but bank's geometry is far
        // cleaner; allow a weak inequality with slack
        assert!(sep(&easy) + 0.02 > sep(&hard) * 0.5);
    }
}

//! Datasets: the paper's Table 2 registry (simulated — see DESIGN.md §4/§5
//! for the substitution rationale), the exact Appendix-C synthetic set,
//! scaling, and splits.

pub mod csvio;
pub mod scaling;
pub mod splits;
pub mod synthetic;
pub mod uci_sim;

use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;

/// A labelled classification dataset with features in [0,1]^n.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// m×n feature matrix.
    pub x: Matrix,
    /// class labels in {0, …, n_classes−1}, length m.
    pub y: Vec<usize>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Matrix, y: Vec<usize>, n_classes: usize) -> Result<Self> {
        if x.rows() != y.len() {
            return Err(AviError::Data(format!(
                "rows {} != labels {}",
                x.rows(),
                y.len()
            )));
        }
        if y.iter().any(|&c| c >= n_classes) {
            return Err(AviError::Data("label out of range".into()));
        }
        Ok(Dataset { name: name.into(), x, y, n_classes })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.y.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    #[inline]
    pub fn n_features(&self) -> usize {
        self.x.cols()
    }

    /// Rows belonging to class k as a fresh matrix (Algorithm 2 Line 2).
    pub fn class_matrix(&self, k: usize) -> Matrix {
        let rows: Vec<Vec<f64>> = (0..self.len())
            .filter(|&i| self.y[i] == k)
            .map(|i| self.x.row(i).to_vec())
            .collect();
        Matrix::from_rows(&rows).expect("uniform row width")
    }

    /// Per-class sample counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &c in &self.y {
            counts[c] += 1;
        }
        counts
    }

    /// Subset by row indices.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let rows: Vec<Vec<f64>> = idx.iter().map(|&i| self.x.row(i).to_vec()).collect();
        let y: Vec<usize> = idx.iter().map(|&i| self.y[i]).collect();
        Dataset {
            name: self.name.clone(),
            x: Matrix::from_rows(&rows).expect("uniform rows"),
            y,
            n_classes: self.n_classes,
        }
    }

    /// First `m` samples (after the dataset's canonical shuffle) — the
    /// paper's "subsets of the full data set of varying sizes" (§6.3).
    pub fn head(&self, m: usize) -> Dataset {
        let idx: Vec<usize> = (0..m.min(self.len())).collect();
        self.subset(&idx)
    }

    /// Reorder features by a permutation (Pearson ordering).
    pub fn permute_features(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.n_features());
        let mut x = Matrix::zeros(self.len(), self.n_features());
        for i in 0..self.len() {
            for (new_j, &old_j) in perm.iter().enumerate() {
                x.set(i, new_j, self.x.get(i, old_j));
            }
        }
        Dataset { name: self.name.clone(), x, y: self.y.clone(), n_classes: self.n_classes }
    }
}

/// The paper's Table 2 registry (plus `synthetic`).  `scale` ∈ (0,1]
/// shrinks sample counts proportionally for quick runs.
pub fn load_registry_dataset(name: &str, scale: f64, seed: u64) -> Result<Dataset> {
    let scaled = |m: usize| ((m as f64 * scale).round() as usize).max(60);
    match name {
        "bank" => uci_sim::bank(scaled(1372), seed),
        "credit" => uci_sim::credit(scaled(30_000), seed),
        "htru" | "htru2" => uci_sim::htru(scaled(17_898), seed),
        "seeds" => uci_sim::seeds(scaled(210), seed),
        "skin" => uci_sim::skin(scaled(245_057), seed),
        "spam" => uci_sim::spam(scaled(4_601), seed),
        "synthetic" => Ok(synthetic::synthetic_dataset(scaled(2_000_000), seed)),
        other => Err(AviError::Data(format!("unknown dataset '{other}'"))),
    }
}

/// Names in the paper's Table 2 order.
pub const REGISTRY: &[&str] = &["bank", "credit", "htru", "seeds", "skin", "spam", "synthetic"];

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_rows(&[
            vec![0.1, 0.2],
            vec![0.3, 0.4],
            vec![0.5, 0.6],
            vec![0.7, 0.8],
        ])
        .unwrap();
        Dataset::new("toy", x, vec![0, 1, 0, 1], 2).unwrap()
    }

    #[test]
    fn class_matrix_selects_rows() {
        let ds = toy();
        let c0 = ds.class_matrix(0);
        assert_eq!(c0.rows(), 2);
        assert_eq!(c0.row(1), &[0.5, 0.6]);
        assert_eq!(ds.class_counts(), vec![2, 2]);
    }

    #[test]
    fn subset_and_head() {
        let ds = toy();
        let s = ds.subset(&[3, 0]);
        assert_eq!(s.y, vec![1, 0]);
        assert_eq!(s.x.row(0), &[0.7, 0.8]);
        assert_eq!(ds.head(2).len(), 2);
    }

    #[test]
    fn permute_features_swaps_columns() {
        let ds = toy();
        let p = ds.permute_features(&[1, 0]);
        assert_eq!(p.x.row(0), &[0.2, 0.1]);
    }

    #[test]
    fn validation() {
        let x = Matrix::zeros(3, 2);
        assert!(Dataset::new("bad", x.clone(), vec![0, 1], 2).is_err());
        assert!(Dataset::new("bad", x, vec![0, 5, 0], 2).is_err());
    }

    #[test]
    fn registry_loads_small() {
        for name in ["bank", "seeds"] {
            let ds = load_registry_dataset(name, 0.1, 42).unwrap();
            assert!(ds.len() >= 60, "{name}");
            // all features in [0,1]
            for v in ds.x.data() {
                assert!((0.0..=1.0).contains(v), "{name}: {v}");
            }
        }
        assert!(load_registry_dataset("nope", 1.0, 0).is_err());
    }
}

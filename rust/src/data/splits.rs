//! Train/test partitions and k-fold cross-validation (paper §6: ten
//! random 60%/40% splits, 3-fold CV for hyperparameters).

use crate::data::Dataset;
use crate::util::rng::Rng;

/// A random train/test split.
pub struct Split {
    pub train: Dataset,
    pub test: Dataset,
}

/// Shuffle and split: `train_frac` of samples go to train.
pub fn train_test_split(ds: &Dataset, train_frac: f64, seed: u64) -> Split {
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let n_train = ((ds.len() as f64) * train_frac).round() as usize;
    let n_train = n_train.clamp(1, ds.len().saturating_sub(1).max(1));
    Split { train: ds.subset(&idx[..n_train]), test: ds.subset(&idx[n_train..]) }
}

/// k-fold CV index pairs (train_idx, val_idx) over `m` samples.
pub fn kfold_indices(m: usize, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && m >= k);
    let mut idx: Vec<usize> = (0..m).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let mut folds: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &sample) in idx.iter().enumerate() {
        folds[i % k].push(sample);
    }
    (0..k)
        .map(|f| {
            let val = folds[f].clone();
            let train: Vec<usize> = (0..k)
                .filter(|&g| g != f)
                .flat_map(|g| folds[g].iter().copied())
                .collect();
            (train, val)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Matrix;

    fn ds(m: usize) -> Dataset {
        let rows: Vec<Vec<f64>> = (0..m).map(|i| vec![i as f64 / m as f64]).collect();
        Dataset::new("t", Matrix::from_rows(&rows).unwrap(), vec![0; m], 1).unwrap()
    }

    #[test]
    fn split_sizes_and_disjointness() {
        let d = ds(100);
        let s = train_test_split(&d, 0.6, 7);
        assert_eq!(s.train.len(), 60);
        assert_eq!(s.test.len(), 40);
        // disjoint: every original value appears exactly once
        let mut all: Vec<i64> = s
            .train
            .x
            .data()
            .iter()
            .chain(s.test.x.data().iter())
            .map(|v| (v * 100.0).round() as i64)
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<i64>>());
    }

    #[test]
    fn split_is_seeded() {
        let d = ds(50);
        let a = train_test_split(&d, 0.6, 1);
        let b = train_test_split(&d, 0.6, 1);
        assert_eq!(a.train.x.data(), b.train.x.data());
        let c = train_test_split(&d, 0.6, 2);
        assert_ne!(a.train.x.data(), c.train.x.data());
    }

    #[test]
    fn kfold_partitions() {
        let folds = kfold_indices(10, 3, 5);
        assert_eq!(folds.len(), 3);
        for (train, val) in &folds {
            assert_eq!(train.len() + val.len(), 10);
            let mut merged: Vec<usize> = train.iter().chain(val.iter()).copied().collect();
            merged.sort_unstable();
            assert_eq!(merged, (0..10).collect::<Vec<usize>>());
        }
        // every sample appears in exactly one validation fold
        let mut vals: Vec<usize> = folds.iter().flat_map(|(_, v)| v.iter().copied()).collect();
        vals.sort_unstable();
        assert_eq!(vals, (0..10).collect::<Vec<usize>>());
    }
}

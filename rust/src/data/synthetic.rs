//! The paper's synthetic dataset — Appendix C, implemented exactly.
//!
//! Two classes in [0,1]³:
//! * class 1: points satisfying `x₁² + 0.01·x₂ + x₃² − 1 = 0`
//! * class 2: points satisfying `x₁² + x₃² − 1.3 = 0`
//!
//! perturbed by additive N(0, 0.05²) noise, then min-max scaled to [0,1]³
//! (the paper preprocesses every dataset that way, §6.1).

use crate::data::scaling::minmax_scale_in_place;
use crate::data::Dataset;
use crate::linalg::dense::Matrix;
use crate::util::rng::Rng;

/// Sample a point on `x1² + a·x2 + x3² = c` with x1, x2 free in [0,1] and
/// x3 solved (rejection on the radicand).
fn sample_on_surface(rng: &mut Rng, a: f64, c: f64) -> [f64; 3] {
    loop {
        let x1 = rng.uniform();
        let x2 = rng.uniform();
        let rad = c - a * x2 - x1 * x1;
        if rad >= 0.0 {
            let x3 = rad.sqrt();
            // keep the branch inside a sane box; the paper scales to [0,1]
            // afterwards anyway
            if x3 <= 1.3 {
                return [x1, x2, x3];
            }
        }
    }
}

/// Generate the Appendix-C synthetic dataset with `m` samples
/// (≈ m/2 per class), noise σ = 0.05, min-max scaled.
pub fn synthetic_dataset(m: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5e7e_71c0);
    let mut x = Matrix::zeros(m, 3);
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        let class = i % 2;
        let p = if class == 0 {
            sample_on_surface(&mut rng, 0.01, 1.0)
        } else {
            sample_on_surface(&mut rng, 0.0, 1.3)
        };
        for (j, pj) in p.iter().enumerate() {
            x.set(i, j, pj + rng.normal_ms(0.0, 0.05));
        }
        y.push(class);
    }
    minmax_scale_in_place(&mut x);
    Dataset { name: "synthetic".into(), x, y, n_classes: 2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_labels() {
        let ds = synthetic_dataset(1000, 1);
        assert_eq!(ds.len(), 1000);
        assert_eq!(ds.n_features(), 3);
        assert_eq!(ds.n_classes, 2);
        let counts = ds.class_counts();
        assert_eq!(counts[0], 500);
        assert_eq!(counts[1], 500);
    }

    #[test]
    fn features_in_unit_box() {
        let ds = synthetic_dataset(500, 2);
        for v in ds.x.data() {
            assert!((0.0..=1.0).contains(v));
        }
    }

    #[test]
    fn classes_lie_near_their_varieties_pre_scaling() {
        // regenerate without scaling to check the defining equations
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let p = sample_on_surface(&mut rng, 0.01, 1.0);
            let r = p[0] * p[0] + 0.01 * p[1] + p[2] * p[2] - 1.0;
            assert!(r.abs() < 1e-12, "class-1 residual {r}");
            let q = sample_on_surface(&mut rng, 0.0, 1.3);
            let r2 = q[0] * q[0] + q[2] * q[2] - 1.3;
            assert!(r2.abs() < 1e-12, "class-2 residual {r2}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synthetic_dataset(100, 3);
        let b = synthetic_dataset(100, 3);
        assert_eq!(a.x.data(), b.x.data());
        let c = synthetic_dataset(100, 4);
        assert_ne!(a.x.data(), c.x.data());
    }
}

//! Minimal CSV I/O: load real datasets when available, dump results.
//!
//! Format: numeric columns, label (integer) in the last column, optional
//! header row (auto-detected).  Used as the optional real-UCI path and by
//! the bench harness for result series.
//!
//! Parsing streams line-by-line through
//! [`crate::storage::ingest::RowGroupReader`] — the same loop chunked
//! ingestion uses — so the file is never held in memory whole and the
//! two paths cannot drift on header/error semantics.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use crate::data::scaling::minmax_scale_in_place;
use crate::data::Dataset;
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::storage::ingest::RowGroupReader;

/// Rows parsed per streaming step (bounds loader memory to one group
/// plus the accumulated feature matrix).
const LOAD_GROUP_ROWS: usize = 8_192;

/// Load `<path>` as a dataset (label = last column, min-max scaled).
pub fn load_csv_dataset(path: &Path, name: &str) -> Result<Dataset> {
    let mut rdr = RowGroupReader::open(path, LOAD_GROUP_ROWS)?;
    let mut feats: Vec<f64> = Vec::new();
    let mut labels: Vec<i64> = Vec::new();
    let mut buf = Vec::new();
    loop {
        let got = rdr.next_group(&mut buf)?;
        if got == 0 {
            break;
        }
        let n = rdr.n_fields().expect("fields known after a non-empty group");
        for r in 0..got {
            let row = &buf[r * n..(r + 1) * n];
            feats.extend_from_slice(&row[..n - 1]);
            labels.push(row[n - 1].round() as i64);
        }
    }
    if labels.is_empty() {
        return Err(AviError::Data(format!("{}: no rows", path.display())));
    }
    let n_feats = rdr.n_fields().unwrap() - 1;
    // remap labels to 0..k
    let mut uniq: Vec<i64> = labels.clone();
    uniq.sort_unstable();
    uniq.dedup();
    let y: Vec<usize> = labels
        .iter()
        .map(|l| uniq.binary_search(l).unwrap())
        .collect();
    let mut x = Matrix::from_flat(labels.len(), n_feats, feats)?;
    minmax_scale_in_place(&mut x);
    Dataset::new(name, x, y, uniq.len())
}

/// Write a simple CSV (header + rows) — bench series output.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut f = fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let line: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_csv_dataset() {
        let dir = std::env::temp_dir().join("avi_scale_csv_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.csv");
        fs::write(&path, "a,b,label\n0.0,2.0,1\n1.0,4.0,0\n0.5,3.0,1\n").unwrap();
        let ds = load_csv_dataset(&path, "toy").unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.n_features(), 2);
        assert_eq!(ds.n_classes, 2);
        assert_eq!(ds.y, vec![1, 0, 1]);
        assert_eq!(ds.x.get(1, 0), 1.0); // scaled
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("avi_scale_csv_test2");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        fs::write(&path, "h\nnot,numbers,here\n").unwrap();
        assert!(load_csv_dataset(&path, "bad").is_err());
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join("avi_scale_csv_test3/nested");
        let path = dir.join("out.csv");
        write_csv(&path, &["x", "y"], &[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("x,y\n1,2\n3,4\n"));
    }
}

//! Benchmark harness (criterion is unavailable in this offline
//! environment; this is the crate's replacement).
//!
//! Three layers:
//! * [`Bencher`] — warmup + repeated timing of a closure, reporting
//!   median/p10/p90 (and writing CSV rows under `target/bench_results/`).
//! * [`Series`] — named (x, y±σ) curves for the paper's figures, printed
//!   as aligned tables plus a crude ASCII log-plot so `cargo bench`
//!   output is directly comparable to the paper.
//! * [`BenchJson`] — the machine-readable perf trajectory: flat
//!   key→value artifacts (`BENCH_<id>.json`) that
//!   `scripts/bench_gate.sh` diffs across commits and fails on
//!   regression.

pub mod figures;

use std::path::PathBuf;

use crate::data::csvio::write_csv;
use crate::util::timer::Timer;
use crate::util::{mean, median, std_dev};

/// Repeat-timing harness.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
}

/// One timing result.
#[derive(Clone, Debug)]
pub struct BenchStat {
    pub name: String,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, iters: 5 }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher { warmup, iters: iters.max(1) }
    }

    /// Time `f` and report stats.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStat {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Timer::start();
            std::hint::black_box(f());
            times.push(t.secs());
        }
        times.sort_by(f64::total_cmp);
        let pick = |q: f64| times[((times.len() - 1) as f64 * q).round() as usize];
        BenchStat {
            name: name.to_string(),
            median_s: median(&times),
            p10_s: pick(0.1),
            p90_s: pick(0.9),
            iters: self.iters,
        }
    }
}

/// Machine-readable bench artifact: flat key→value cells written as
/// `target/bench_results/BENCH_<id>.json`, one `"key": value` pair per
/// line so `scripts/bench_gate.sh` can parse and diff trajectories with
/// plain sed/awk (the container has no jq).  Timing cells end in `_ns`
/// by convention ([`BenchJson::ns`]); the regression gate compares only
/// those keys.
pub struct BenchJson {
    id: String,
    cells: Vec<(String, String)>,
}

impl BenchJson {
    pub fn new(id: impl Into<String>) -> Self {
        BenchJson { id: id.into(), cells: Vec::new() }
    }

    /// Raw numeric cell (counters, speedups, error magnitudes).
    pub fn num(&mut self, key: &str, v: f64) {
        self.cells.push((key.to_string(), format!("{v}")));
    }

    /// Integer cell (dispatch/panel counters).
    pub fn int(&mut self, key: &str, v: u64) {
        self.cells.push((key.to_string(), format!("{v}")));
    }

    /// String cell (kernel names, modes).
    pub fn str_cell(&mut self, key: &str, v: &str) {
        self.cells.push((key.to_string(), format!("\"{}\"", crate::util::json_escape(v))));
    }

    /// Timing cell: `secs` recorded as nanoseconds under `<key>_ns` —
    /// the suffix the regression gate keys on.
    pub fn ns(&mut self, key: &str, secs: f64) {
        self.num(&format!("{key}_ns"), secs * 1e9);
    }

    /// Write `target/bench_results/BENCH_<id>.json` and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = PathBuf::from("target/bench_results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.id));
        let mut body = String::from("{\n");
        body.push_str(&format!("  \"bench_id\": \"{}\"", crate::util::json_escape(&self.id)));
        for (k, v) in &self.cells {
            body.push_str(&format!(",\n  \"{}\": {v}", crate::util::json_escape(k)));
        }
        body.push_str("\n}\n");
        std::fs::write(&path, body)?;
        println!("[json] {}", path.display());
        Ok(path)
    }
}

/// A named measurement series for figure reproduction: y(x) ± σ.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64, f64)>, // (x, mean, std)
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    /// Add a point from repeated observations.
    pub fn push_obs(&mut self, x: f64, obs: &[f64]) {
        self.points.push((x, mean(obs), std_dev(obs)));
    }
}

/// Print a figure-style block: aligned table + ASCII log-log sketch, and
/// write `target/bench_results/<id>.csv`.
pub fn report_figure(id: &str, x_label: &str, series: &[Series]) {
    println!("\n=== {id} ===");
    // table
    print!("{x_label:>12}");
    for s in series {
        print!(" {:>18}", s.name);
    }
    println!();
    let xs: Vec<f64> = series
        .first()
        .map(|s| s.points.iter().map(|p| p.0).collect())
        .unwrap_or_default();
    for (i, x) in xs.iter().enumerate() {
        print!("{x:>12.0}");
        for s in series {
            if let Some(&(_, m, sd)) = s.points.get(i) {
                print!(" {:>10.4}±{:<7.4}", m, sd);
            } else {
                print!(" {:>18}", "-");
            }
        }
        println!();
    }
    ascii_loglog(series);
    // CSV
    let mut header: Vec<String> = vec![x_label.to_string()];
    for s in series {
        header.push(format!("{}_mean", s.name));
        header.push(format!("{}_std", s.name));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut rows = Vec::new();
    for (i, x) in xs.iter().enumerate() {
        let mut row = vec![*x];
        for s in series {
            if let Some(&(_, m, sd)) = s.points.get(i) {
                row.push(m);
                row.push(sd);
            } else {
                row.push(f64::NAN);
                row.push(f64::NAN);
            }
        }
        rows.push(row);
    }
    let path = PathBuf::from("target/bench_results").join(format!("{id}.csv"));
    if let Err(e) = write_csv(&path, &header_refs, &rows) {
        eprintln!("(csv write failed: {e})");
    } else {
        println!("[csv] {}", path.display());
    }
}

/// Tiny ASCII log-log plot (good enough to eyeball slopes/crossovers).
fn ascii_loglog(series: &[Series]) {
    const W: usize = 64;
    const H: usize = 16;
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|&(x, y, _)| (x, y)))
        .filter(|&(x, y)| x > 0.0 && y > 0.0)
        .collect();
    if pts.len() < 2 {
        return;
    }
    let (x0, x1) = pts.iter().fold((f64::MAX, f64::MIN), |(a, b), &(x, _)| {
        (a.min(x.ln()), b.max(x.ln()))
    });
    let (y0, y1) = pts.iter().fold((f64::MAX, f64::MIN), |(a, b), &(_, y)| {
        (a.min(y.ln()), b.max(y.ln()))
    });
    if x1 <= x0 || y1 <= y0 {
        return;
    }
    let mut grid = vec![vec![' '; W]; H];
    let marks = ['o', '+', 'x', '*', '#', '@', '%', '&'];
    for (si, s) in series.iter().enumerate() {
        for &(x, y, _) in &s.points {
            if x <= 0.0 || y <= 0.0 {
                continue;
            }
            let cx = (((x.ln() - x0) / (x1 - x0)) * (W - 1) as f64).round() as usize;
            let cy = (((y.ln() - y0) / (y1 - y0)) * (H - 1) as f64).round() as usize;
            grid[H - 1 - cy][cx] = marks[si % marks.len()];
        }
    }
    println!("  (log-log sketch; {} )",
        series
            .iter()
            .enumerate()
            .map(|(i, s)| format!("{}={}", marks[i % marks.len()], s.name))
            .collect::<Vec<_>>()
            .join(", "));
    for row in grid {
        println!("  |{}", row.into_iter().collect::<String>());
    }
    println!("  +{}", "-".repeat(W));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_reports_ordered_percentiles() {
        let b = Bencher::new(0, 7);
        let stat = b.run("spin", || {
            std::hint::black_box((0..2000).map(|i| i as f64).sum::<f64>())
        });
        assert!(stat.p10_s <= stat.median_s);
        assert!(stat.median_s <= stat.p90_s);
        assert_eq!(stat.iters, 7);
        assert_eq!(stat.name, "spin");
    }

    #[test]
    fn series_accumulates_stats() {
        let mut s = Series::new("t");
        s.push_obs(10.0, &[1.0, 2.0, 3.0]);
        assert_eq!(s.points.len(), 1);
        let (x, m, sd) = s.points[0];
        assert_eq!(x, 10.0);
        assert_eq!(m, 2.0);
        assert!(sd > 0.9 && sd < 1.1);
    }

    #[test]
    fn bench_json_writes_flat_gate_parsable_artifact() {
        let mut j = BenchJson::new("unit_test_bench");
        j.ns("kernel_m1000", 1.5e-3);
        j.int("dispatches", 7);
        j.num("speedup", 2.25);
        j.str_cell("mode", "exact");
        let path = j.write().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("{\n"), "{text}");
        assert!(text.ends_with("}\n"), "{text}");
        assert!(text.contains("\"bench_id\": \"unit_test_bench\""));
        // the _ns convention the gate's sed parser keys on: one pair per line
        assert!(text.contains("\"kernel_m1000_ns\": 1500000"), "{text}");
        assert!(text.contains("\"dispatches\": 7"));
        assert!(text.contains("\"mode\": \"exact\""));
    }

    #[test]
    fn report_figure_writes_csv() {
        let mut s = Series::new("algo");
        s.push_obs(100.0, &[0.5]);
        s.push_obs(1000.0, &[5.0]);
        report_figure("unit_test_fig", "m", &[s]);
        let path = std::path::Path::new("target/bench_results/unit_test_fig.csv");
        assert!(path.exists());
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("algo_mean"));
    }
}

//! Paper-figure experiment runners, shared by `cargo bench` targets and
//! the `examples/` drivers.  Each function regenerates the data series of
//! one figure/table of the paper (same workloads, same protocol; sizes
//! scaled by [`SweepSpec::scale`] so CI-class machines finish quickly —
//! crank `scale`/`runs` up to approach the paper's full sweeps).

use crate::baselines::abm::{Abm, AbmConfig};
use crate::baselines::vca::{Vca, VcaConfig};
use crate::bench::Series;
use crate::data::{load_registry_dataset, Dataset};
use crate::error::Result;
use crate::oavi::{Oavi, OaviConfig};
use crate::ordering::{order_features, FeatureOrdering};
use crate::util::timer::Timer;

/// Sweep protocol for the training-time figures (paper §6.3).
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// registry dataset names.
    pub datasets: Vec<String>,
    /// fractions of the (scaled) dataset to train on.
    pub fractions: Vec<f64>,
    /// repetitions per point (paper: 10).
    pub runs: usize,
    /// vanishing parameter (paper: 0.005).
    pub psi: f64,
    /// dataset scale multiplier vs the paper's full sizes.
    pub scale: f64,
    pub seed: u64,
}

impl SweepSpec {
    /// Quick defaults: ~minutes on a laptop-class CPU.
    pub fn quick() -> Self {
        SweepSpec {
            datasets: vec!["bank".into(), "htru".into(), "skin".into(), "synthetic".into()],
            fractions: vec![0.25, 0.5, 0.75, 1.0],
            runs: 3,
            psi: 0.005,
            scale: 0.02,
            seed: 0xF16,
        }
    }
}

/// A generator-constructing algorithm under timing test.
#[derive(Clone, Copy, Debug)]
pub enum TimedMethod {
    Oavi(OaviConfig),
    Abm(AbmConfig),
    Vca(VcaConfig),
}

impl TimedMethod {
    pub fn name(&self) -> String {
        match self {
            TimedMethod::Oavi(c) => c.name(),
            TimedMethod::Abm(_) => "ABM".into(),
            TimedMethod::Vca(_) => "VCA".into(),
        }
    }

    fn with_psi(&self, psi: f64) -> TimedMethod {
        match *self {
            TimedMethod::Oavi(mut c) => {
                c.psi = psi;
                TimedMethod::Oavi(c)
            }
            TimedMethod::Abm(mut c) => {
                c.psi = psi;
                TimedMethod::Abm(c)
            }
            TimedMethod::Vca(mut c) => {
                c.psi = psi;
                TimedMethod::Vca(c)
            }
        }
    }

    /// Fit once per class (the §6.3 protocol) and return wall seconds.
    fn time_fit(&self, ds: &Dataset) -> Result<f64> {
        let timer = Timer::start();
        for k in 0..ds.n_classes {
            let xk = ds.class_matrix(k);
            match self {
                TimedMethod::Oavi(cfg) => {
                    Oavi::new(*cfg).fit(&xk)?;
                }
                TimedMethod::Abm(cfg) => {
                    Abm::new(*cfg).fit(&xk)?;
                }
                TimedMethod::Vca(cfg) => {
                    Vca::new(*cfg).fit(&xk)?;
                }
            }
        }
        Ok(timer.secs())
    }
}

/// Training-time-vs-m sweep: one `(dataset, series-per-method)` block per
/// dataset — the common engine behind Figures 2, 3 and 4.
pub fn training_time_sweep(
    methods: &[TimedMethod],
    spec: &SweepSpec,
) -> Result<Vec<(String, Vec<Series>)>> {
    let mut out = Vec::new();
    for ds_name in &spec.datasets {
        let full = load_registry_dataset(ds_name, spec.scale, spec.seed)?;
        // Pearson-order once (monomial-aware algorithms; §6.1)
        let perm = order_features(&full.x, FeatureOrdering::Pearson);
        let full = full.permute_features(&perm);
        let mut series: Vec<Series> =
            methods.iter().map(|m| Series::new(m.name())).collect();
        for &frac in &spec.fractions {
            let m_sub = ((full.len() as f64) * frac).round() as usize;
            let sub = full.head(m_sub.max(40));
            for (mi, method) in methods.iter().enumerate() {
                let method = method.with_psi(spec.psi);
                let mut times = Vec::with_capacity(spec.runs);
                for _ in 0..spec.runs {
                    times.push(method.time_fit(&sub)?);
                }
                series[mi].push_obs(sub.len() as f64, &times);
            }
        }
        out.push((ds_name.clone(), series));
    }
    Ok(out)
}

/// Figure 2: PCGAVI vs BPCGAVI.
pub fn fig2_methods() -> Vec<TimedMethod> {
    vec![
        TimedMethod::Oavi(OaviConfig::pcgavi(0.005)),
        TimedMethod::Oavi(OaviConfig::bpcgavi(0.005)),
    ]
}

/// Figure 3: BPCGAVI vs BPCGAVI-WIHB vs CGAVI-IHB.
pub fn fig3_methods() -> Vec<TimedMethod> {
    vec![
        TimedMethod::Oavi(OaviConfig::bpcgavi(0.005)),
        TimedMethod::Oavi(OaviConfig::bpcgavi_wihb(0.005)),
        TimedMethod::Oavi(OaviConfig::cgavi_ihb(0.005)),
    ]
}

/// Figure 4: CGAVI-IHB, BPCGAVI-WIHB, AGDAVI-IHB, ABM, VCA.
pub fn fig4_methods() -> Vec<TimedMethod> {
    vec![
        TimedMethod::Oavi(OaviConfig::cgavi_ihb(0.005)),
        TimedMethod::Oavi(OaviConfig::bpcgavi_wihb(0.005)),
        TimedMethod::Oavi(OaviConfig::agdavi_ihb(0.005)),
        TimedMethod::Abm(AbmConfig::new(0.005)),
        TimedMethod::Vca(VcaConfig::new(0.005)),
    ]
}

/// Figure 1 (left): the Theorem 4.3 bound as a function of ψ for several n.
pub fn fig1_bound_curves(ns: &[usize], psis: &[f64]) -> Vec<Series> {
    ns.iter()
        .map(|&n| {
            let mut s = Series::new(format!("n={n}"));
            for &psi in psis {
                let cfg = OaviConfig::cgavi_ihb(psi);
                s.points.push((psi, cfg.size_bound(n), 0.0));
            }
            s
        })
        .collect()
}

/// Figure 1 (right): theoretical bound vs empirical |G|+|O| on random
/// data (m samples, ψ fixed, n sweep), plus the paper's n⁴ guide line.
pub fn fig1_empirical(
    m: usize,
    ns: &[usize],
    psi: f64,
    runs: usize,
    seed: u64,
) -> Result<Vec<Series>> {
    use crate::util::rng::Rng;
    let mut bound = Series::new("Theorem 4.3 bound");
    let mut empirical = Series::new("CGAVI |G|+|O|");
    let mut guide = Series::new("n^4");
    let cfg = OaviConfig::cgavi_ihb(psi);
    for &n in ns {
        bound.points.push((n as f64, cfg.size_bound(n), 0.0));
        guide.points.push((n as f64, (n as f64).powi(4), 0.0));
        let mut sizes = Vec::with_capacity(runs);
        for r in 0..runs {
            let mut rng = Rng::new(seed ^ ((n as u64) << 8) ^ r as u64);
            let mut x = crate::linalg::dense::Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    x.set(i, j, rng.uniform());
                }
            }
            let model = Oavi::new(cfg).fit(&x)?;
            sizes.push(model.total_size() as f64);
        }
        empirical.push_obs(n as f64, &sizes);
    }
    Ok(vec![bound, empirical, guide])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_produces_monotone_sizes() {
        let spec = SweepSpec {
            datasets: vec!["bank".into()],
            fractions: vec![0.5, 1.0],
            runs: 1,
            psi: 0.01,
            scale: 0.05,
            seed: 1,
        };
        let blocks =
            training_time_sweep(&[TimedMethod::Oavi(OaviConfig::cgavi_ihb(0.01))], &spec)
                .unwrap();
        assert_eq!(blocks.len(), 1);
        let series = &blocks[0].1[0];
        assert_eq!(series.points.len(), 2);
        assert!(series.points[0].0 < series.points[1].0);
    }

    #[test]
    fn fig1_bound_is_monotone_in_n_and_psi() {
        let curves = fig1_bound_curves(&[2, 8], &[0.1, 0.01]);
        // larger n ⇒ larger bound at the same ψ
        assert!(curves[1].points[0].1 > curves[0].points[0].1);
        // smaller ψ ⇒ larger bound at the same n
        assert!(curves[0].points[1].1 > curves[0].points[0].1);
    }

    #[test]
    fn fig1_empirical_below_bound() {
        let series = fig1_empirical(300, &[2, 3], 0.05, 1, 3).unwrap();
        let bound = &series[0];
        let emp = &series[1];
        for (b, e) in bound.points.iter().zip(emp.points.iter()) {
            assert!(e.1 <= b.1, "empirical {} above bound {}", e.1, b.1);
        }
    }
}

//! Whole-pipeline persistence: ordering permutation + per-class generator
//! sets + SVM weights, as one JSON document.  Covers monomial-aware
//! models (OAVI family, ABM); VCA's op-DAG has its own in-memory
//! representation and is not serialized (returns an error).

use std::fs;
use std::path::Path;

use crate::error::{AviError, Result};
use crate::oavi::persist as gs_persist;
use crate::pipeline::{ClassModel, FittedTransformer, PipelineModel};
use crate::svm::linear::{LinearSvm, LinearSvmConfig};

/// Serialize a trained pipeline to JSON.
pub fn to_json(model: &PipelineModel) -> Result<String> {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"perm\": [{}],\n",
        model
            .perm
            .iter()
            .map(|p| p.to_string())
            .collect::<Vec<_>>()
            .join(",")
    ));
    out.push_str(&format!("  \"n_classes\": {},\n", model.n_classes));
    out.push_str(&format!(
        "  \"method\": {:?},\n",
        model.transformer.method_name
    ));
    // per-class generator sets (nested JSON from oavi::persist)
    out.push_str("  \"classes\": [\n");
    for (i, cm) in model.transformer.per_class.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        match cm {
            ClassModel::MonomialAware(gs) => out.push_str(&gs_persist::to_json(gs)),
            ClassModel::Vca(_) => {
                return Err(AviError::Config(
                    "pipeline persistence does not support VCA models".into(),
                ))
            }
        }
    }
    out.push_str("\n  ],\n");
    // SVM weights
    out.push_str("  \"svm\": {\n");
    out.push_str(&format!("    \"lambda\": {:e},\n", model.svm.config.lambda));
    out.push_str("    \"heads\": [\n");
    for (hi, (w, b)) in model.svm.weights.iter().enumerate() {
        if hi > 0 {
            out.push_str(",\n");
        }
        let ws: Vec<String> = w.iter().map(|v| format!("{v:e}")).collect();
        out.push_str(&format!(
            "      {{\"bias\": {:e}, \"w\": [{}]}}",
            b,
            ws.join(",")
        ));
    }
    out.push_str("\n    ]\n  }\n}\n");
    Ok(out)
}

/// Parse a pipeline back.
pub fn from_json(text: &str) -> Result<PipelineModel> {
    // perm
    let perm_src = extract_after(text, "\"perm\":")?;
    let perm: Vec<usize> = parse_num_list(&perm_src)?
        .into_iter()
        .map(|v| v as usize)
        .collect();
    let n_classes = extract_num(text, "\"n_classes\":")? as usize;
    let method_name = {
        let pos = text
            .find("\"method\":")
            .ok_or_else(|| AviError::Data("persist: missing method".into()))?;
        let rest = &text[pos + 9..];
        let q1 = rest.find('"').ok_or_else(|| AviError::Data("bad method".into()))?;
        let q2 = rest[q1 + 1..]
            .find('"')
            .ok_or_else(|| AviError::Data("bad method".into()))?;
        rest[q1 + 1..q1 + 1 + q2].to_string()
    };

    // classes: split on the top-level generator-set objects.  Each class
    // document starts with `{\n  "n_vars":` (the oavi::persist format).
    let classes_pos = text
        .find("\"classes\":")
        .ok_or_else(|| AviError::Data("persist: missing classes".into()))?;
    let svm_pos = text
        .find("\"svm\":")
        .ok_or_else(|| AviError::Data("persist: missing svm".into()))?;
    let classes_src = &text[classes_pos..svm_pos];
    let mut per_class = Vec::new();
    let mut search = 0usize;
    while let Some(rel) = classes_src[search..].find("\"n_vars\":") {
        let start = search + rel;
        let end = classes_src[start..]
            .find("\"generators\"")
            .and_then(|g| {
                // the class document ends at the ]\n} closing the
                // generators array
                classes_src[start + g..].find("]\n}").map(|e| start + g + e + 3)
            })
            .ok_or_else(|| AviError::Data("persist: unterminated class".into()))?;
        // include a bit of left context so extract finds keys
        let doc = &classes_src[start.saturating_sub(2)..end];
        per_class.push(ClassModel::MonomialAware(gs_persist::from_json(doc)?));
        search = end;
    }
    if per_class.len() != n_classes {
        return Err(AviError::Data(format!(
            "persist: {} classes parsed, expected {n_classes}",
            per_class.len()
        )));
    }

    // svm
    let svm_src = &text[svm_pos..];
    let lambda = extract_num(svm_src, "\"lambda\":")?;
    let mut weights = Vec::new();
    let mut search = 0usize;
    while let Some(rel) = svm_src[search..].find("\"bias\":") {
        let start = search + rel;
        let bias = extract_num(&svm_src[start..], "\"bias\":")?;
        let w_src = extract_after(&svm_src[start..], "\"w\":")?;
        let w = parse_num_list(&w_src)?;
        search = start + 7;
        weights.push((w, bias));
    }
    if weights.is_empty() {
        return Err(AviError::Data("persist: no svm heads".into()));
    }
    let svm = LinearSvm {
        weights,
        n_classes,
        config: LinearSvmConfig { lambda, ..Default::default() },
        iters: vec![],
    };
    Ok(PipelineModel {
        perm,
        transformer: FittedTransformer { method_name, per_class },
        svm,
        n_classes,
    })
}

/// Save to file.
pub fn save(model: &PipelineModel, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    fs::write(path, to_json(model)?)?;
    Ok(())
}

/// Load from file.
pub fn load(path: &Path) -> Result<PipelineModel> {
    from_json(&fs::read_to_string(path)?)
}

fn extract_after(text: &str, key: &str) -> Result<String> {
    let pos = text
        .find(key)
        .ok_or_else(|| AviError::Data(format!("persist: missing {key}")))?;
    let rest = &text[pos + key.len()..];
    let start = rest
        .find('[')
        .ok_or_else(|| AviError::Data(format!("persist: {key} not an array")))?;
    let end = rest[start..]
        .find(']')
        .ok_or_else(|| AviError::Data("persist: unbalanced".into()))?;
    Ok(rest[start + 1..start + end].to_string())
}

fn extract_num(text: &str, key: &str) -> Result<f64> {
    let pos = text
        .find(key)
        .ok_or_else(|| AviError::Data(format!("persist: missing {key}")))?;
    let rest = &text[pos + key.len()..];
    let end = rest.find([',', '}', '\n']).unwrap_or(rest.len());
    rest[..end]
        .trim()
        .parse::<f64>()
        .map_err(|e| AviError::Data(format!("persist: {key}: {e}")))
}

fn parse_num_list(src: &str) -> Result<Vec<f64>> {
    if src.trim().is_empty() {
        return Ok(Vec::new());
    }
    src.split(',')
        .map(|t| {
            t.trim()
                .parse::<f64>()
                .map_err(|e| AviError::Data(format!("persist: list: {e}")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::oavi::OaviConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, GeneratorMethod, PipelineConfig};
    use crate::svm::linear::LinearSvmConfig;

    fn trained() -> PipelineModel {
        let ds = synthetic_dataset(400, 31);
        train_pipeline(
            &PipelineConfig {
                method: GeneratorMethod::Oavi(OaviConfig::cgavi_ihb(0.005)),
                svm: LinearSvmConfig::default(),
                ordering: FeatureOrdering::Pearson,
            },
            &ds,
        )
        .unwrap()
    }

    #[test]
    fn pipeline_roundtrip_predicts_identically() {
        let model = trained();
        let json = to_json(&model).unwrap();
        let back = from_json(&json).unwrap();
        let ds = synthetic_dataset(50, 32);
        assert_eq!(model.predict(&ds.x), back.predict(&ds.x));
        assert_eq!(model.perm, back.perm);
        assert_eq!(
            model.transformer.total_size(),
            back.transformer.total_size()
        );
    }

    #[test]
    fn file_roundtrip() {
        let model = trained();
        let path = std::env::temp_dir().join("avi_scale_pipe/model.json");
        save(&model, &path).unwrap();
        let back = load(&path).unwrap();
        let ds = synthetic_dataset(20, 33);
        assert_eq!(model.predict(&ds.x), back.predict(&ds.x));
    }

    #[test]
    fn vca_is_rejected() {
        use crate::baselines::vca::VcaConfig;
        let ds = synthetic_dataset(200, 34);
        let model = train_pipeline(
            &PipelineConfig {
                method: GeneratorMethod::Vca(VcaConfig::new(0.01)),
                svm: LinearSvmConfig::default(),
                ordering: FeatureOrdering::Native,
            },
            &ds,
        )
        .unwrap();
        assert!(to_json(&model).is_err());
    }

    #[test]
    fn garbage_rejected() {
        assert!(from_json("{}").is_err());
        assert!(from_json("{\"perm\": [0], \"n_classes\": 2}").is_err());
    }
}

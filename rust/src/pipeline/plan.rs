//! Pipeline-level compiled transform plans: one [`TransformPlan`] per
//! fitted [`PipelineModel`], built once (at registry insert / model
//! activation) and shared behind an `Arc` by every serving worker.
//!
//! A plan composes the per-class [`PreparedTransform`]s (see
//! [`crate::estimator::plan`]) with the pipeline's two remaining
//! per-request chores — the feature permutation and the SVM decision —
//! over a [`TransformScratch`] of reusable buffers, so the steady-state
//! request path performs **zero transform allocations**: no eval store,
//! no `C`/`U` rebuild, no intermediate per-class blocks, no permuted
//! copy of `x` beyond the resident scratch matrix.  Each class writes
//! its feature columns directly into its column range of one
//! concatenated row-major slab.
//!
//! Dense-kernel plans are bitwise identical to
//! [`PipelineModel::predict_scores_with_backend`] on every backend (the
//! transform is per-row independent; see `tests/transform_plan_parity.rs`).

use std::sync::Arc;
use std::time::Instant;

use crate::estimator::plan::{PlanPolicy, PlanScratch, PreparedTransform};
use crate::linalg::dense::Matrix;
use crate::pipeline::PipelineModel;

/// Reusable per-worker serving scratch: the estimator-level term buffer
/// plus the pipeline-level permuted-input and feature slabs.  One
/// instance per serving thread; everything grows to the high-water mark
/// and is then reused.
#[derive(Debug, Default)]
pub struct TransformScratch {
    plan: PlanScratch,
    xp: Matrix,
    feats: Vec<f64>,
}

impl TransformScratch {
    pub fn new() -> Self {
        TransformScratch::default()
    }

    /// Buffer growth events across *all* scratch slabs since
    /// construction — must stay constant in steady state (the serve
    /// smoke and bench assert it).
    pub fn grows(&self) -> u64 {
        self.plan.grows()
    }
}

/// A pipeline transform compiled once per fitted model: per-class
/// prepared transforms, their column offsets, and the build cost —
/// everything x-independent hoisted out of the request path.
#[derive(Debug)]
pub struct TransformPlan {
    model: Arc<PipelineModel>,
    class_plans: Vec<Box<dyn PreparedTransform>>,
    offsets: Vec<usize>,
    total_cols: usize,
    build_micros: u64,
    sparse_classes: usize,
    flops_saved_per_row: u64,
}

impl TransformPlan {
    /// Compile a plan for `model` under `policy` (dense exact by
    /// default; packed sparse kernels opt-in per class past the measured
    /// threshold).
    pub fn build(model: Arc<PipelineModel>, policy: &PlanPolicy) -> TransformPlan {
        let t0 = Instant::now();
        let n_classes = model.transformer.per_class.len();
        let mut class_plans = Vec::with_capacity(n_classes);
        let mut offsets = Vec::with_capacity(n_classes);
        let mut total_cols = 0usize;
        for c in &model.transformer.per_class {
            let p = c.prepare(policy);
            offsets.push(total_cols);
            total_cols += p.n_cols();
            class_plans.push(p);
        }
        let sparse_classes = class_plans.iter().filter(|p| p.sparse_engaged()).count();
        let flops_saved_per_row = class_plans.iter().map(|p| p.flops_saved_per_row()).sum();
        TransformPlan {
            model,
            class_plans,
            offsets,
            total_cols,
            build_micros: t0.elapsed().as_micros() as u64,
            sparse_classes,
            flops_saved_per_row,
        }
    }

    /// The model this plan was compiled for.
    pub fn model(&self) -> &Arc<PipelineModel> {
        &self.model
    }

    /// Total (FT) feature columns across classes.
    pub fn total_cols(&self) -> usize {
        self.total_cols
    }

    /// Wall-clock microseconds the compile took.
    pub fn build_micros(&self) -> u64 {
        self.build_micros
    }

    /// Number of classes served by the packed sparse kernel.
    pub fn sparse_classes(&self) -> usize {
        self.sparse_classes
    }

    /// Whether any class engaged the packed sparse kernel.
    pub fn sparse_engaged(&self) -> bool {
        self.sparse_classes > 0
    }

    /// Multiply-adds skipped per transformed row by the packed kernels
    /// (0 on the dense default path).
    pub fn flops_saved_per_row(&self) -> u64 {
        self.flops_saved_per_row
    }

    /// Run one zero-row request through the plan so every scratch slab
    /// reaches its steady-state size before real traffic (called at
    /// plan adoption, ahead of the first request).
    pub fn warm(&self, scratch: &mut TransformScratch) {
        let probe = Matrix::zeros(1, self.model.perm.len());
        let _ = self.predict_scores(&probe, scratch);
    }

    /// Labels **and** per-class decision scores through the compiled
    /// plan — the serving reply payload, bitwise identical to
    /// [`PipelineModel::predict_scores_with_backend`] when every class
    /// runs the dense kernel.  Steady state touches only the scratch
    /// slabs plus the reply vectors.
    pub fn predict_scores(
        &self,
        x: &Matrix,
        scratch: &mut TransformScratch,
    ) -> (Vec<usize>, Vec<Vec<f64>>) {
        let m = x.rows();
        let n = self.model.perm.len();
        if scratch.xp.rows() != m || scratch.xp.cols() != n {
            scratch.xp = Matrix::zeros(m, n);
            scratch.plan.note_grow();
        }
        // same element writes as the legacy permute_cols
        for i in 0..m {
            for (new_j, &old_j) in self.model.perm.iter().enumerate() {
                scratch.xp.set(i, new_j, x.get(i, old_j));
            }
        }
        let total = self.total_cols;
        if scratch.feats.len() < m * total {
            scratch.plan.note_grow();
            scratch.feats.resize(m * total, 0.0);
        }
        let mut feats = std::mem::take(&mut scratch.feats);
        for (p, &off) in self.class_plans.iter().zip(self.offsets.iter()) {
            p.transform_into(&scratch.xp, &mut scratch.plan, &mut feats[..m * total], total, off);
        }
        let svm = &self.model.svm;
        let mut labels = Vec::with_capacity(m);
        let mut scores = Vec::with_capacity(m);
        for i in 0..m {
            let d = svm.decision_row(&feats[i * total..(i + 1) * total]);
            labels.push(svm.label_from_decision(&d));
            scores.push(d);
        }
        scratch.feats = feats;
        (labels, scores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synthetic::synthetic_dataset;
    use crate::estimator::EstimatorConfig;
    use crate::ordering::FeatureOrdering;
    use crate::pipeline::{train_pipeline, PipelineConfig};
    use crate::svm::linear::LinearSvmConfig;

    fn trained(method: &str) -> Arc<PipelineModel> {
        let ds = synthetic_dataset(400, 9);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::parse(method, 0.01).unwrap(),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        Arc::new(train_pipeline(&cfg, &ds).unwrap())
    }

    #[test]
    fn plan_predictions_are_bitwise_identical_to_legacy() {
        for method in ["cgavi-ihb", "vca"] {
            let model = trained(method);
            let plan = TransformPlan::build(Arc::clone(&model), &PlanPolicy::default());
            let ds = synthetic_dataset(57, 9);
            let (legacy_labels, legacy_scores) =
                model.predict_scores_with_backend(&ds.x, &NativeBackend);
            let mut scratch = TransformScratch::new();
            let (labels, scores) = plan.predict_scores(&ds.x, &mut scratch);
            assert_eq!(labels, legacy_labels, "{method}");
            for (a, b) in scores.iter().zip(legacy_scores.iter()) {
                let ab: Vec<u64> = a.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u64> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, bb, "{method}: score bits diverged");
            }
        }
    }

    #[test]
    fn warmed_plan_serves_single_rows_without_scratch_growth() {
        let model = trained("cgavi-ihb");
        let plan = TransformPlan::build(Arc::clone(&model), &PlanPolicy::default());
        let mut scratch = TransformScratch::new();
        plan.warm(&mut scratch);
        let after_warm = scratch.grows();
        let ds = synthetic_dataset(40, 9);
        for i in 0..ds.x.rows() {
            let row = Matrix::from_rows(&[ds.x.row(i).to_vec()]).unwrap();
            let _ = plan.predict_scores(&row, &mut scratch);
        }
        assert_eq!(scratch.grows(), after_warm, "steady state must not reallocate");
        assert!(plan.build_micros() < 10_000_000);
        assert_eq!(plan.total_cols(), model.transformer.n_generators());
    }
}

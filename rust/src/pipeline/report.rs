//! Table-3 style experiment cells: (method × dataset) → test error,
//! hyperparameter-optimization time, test time, |G|+|O|, degree, SPAR —
//! averaged over random 60/40 splits, with 3-fold CV inside each split
//! (paper §6.2 protocol).  Generator methods are addressed through the
//! estimator layer, so a cell is algorithm-agnostic.

use crate::coordinator::pool::ThreadPool;
use crate::data::splits::train_test_split;
use crate::data::Dataset;
use crate::error::Result;
use crate::estimator::EstimatorConfig;
use crate::ordering::FeatureOrdering;
use crate::pipeline::gridsearch::{grid_search, grid_search_kernel_svm};
use crate::pipeline::{train_pipeline, PipelineConfig};
use crate::svm::kernel::PolyKernelSvm;
use crate::svm::linear::LinearSvmConfig;
use crate::svm::metrics::error_rate;
use crate::util::timer::Timer;
use crate::util::{mean, std_dev};

/// A Table-3 column entry: generator method + SVM, or the kernel baseline.
#[derive(Clone, Copy, Debug)]
pub enum Method {
    /// estimator (OAVI family, ABM, VCA) + linear SVM.
    Estimator(EstimatorConfig),
    /// polynomial-kernel SVM baseline.
    KernelSvm,
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Estimator(e) => format!("{}+SVM", e.name()),
            Method::KernelSvm => "SVM".into(),
        }
    }
}

/// Experiment protocol knobs.
#[derive(Clone, Copy, Debug)]
pub struct Protocol {
    pub n_splits: usize,
    pub train_frac: f64,
    pub cv_folds: usize,
    pub psis: &'static [f64],
    pub lambdas: &'static [f64],
    pub ordering: FeatureOrdering,
    pub seed: u64,
}

impl Default for Protocol {
    fn default() -> Self {
        Protocol {
            n_splits: 10,
            train_frac: 0.6,
            cv_folds: 3,
            psis: super::gridsearch::PSI_GRID,
            lambdas: super::gridsearch::LAMBDA_GRID,
            ordering: FeatureOrdering::Pearson,
            seed: 0xAB1E,
        }
    }
}

/// One (method × dataset) cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub method: String,
    pub dataset: String,
    pub error_mean: f64,
    pub error_std: f64,
    /// hyperparameter search + final refit, seconds (mean over splits).
    pub hyper_secs: f64,
    /// test-set evaluation seconds (mean).
    pub test_secs: f64,
    /// Σ_i |G^i|+|O^i| (generator methods only; 0 for kernel SVM).
    pub size: f64,
    /// average generator degree.
    pub degree: f64,
    /// (SPAR).
    pub spar: f64,
}

/// Run one cell of Table 3.
pub fn run_cell(
    method: Method,
    ds: &Dataset,
    protocol: &Protocol,
    pool: &ThreadPool,
) -> Result<CellResult> {
    let mut errors = Vec::new();
    let mut hyper_times = Vec::new();
    let mut test_times = Vec::new();
    let mut sizes = Vec::new();
    let mut degrees = Vec::new();
    let mut spars = Vec::new();

    for split_i in 0..protocol.n_splits {
        let split = train_test_split(ds, protocol.train_frac, protocol.seed + split_i as u64);
        match method {
            Method::Estimator(est) => {
                let hyper_timer = Timer::start();
                let gs = grid_search(
                    std::slice::from_ref(&est),
                    protocol.ordering,
                    &split.train,
                    protocol.psis,
                    protocol.lambdas,
                    protocol.cv_folds,
                    protocol.seed + 100 + split_i as u64,
                    pool,
                )?;
                // refit on the whole training split with the best combo
                let cfg = PipelineConfig {
                    estimator: gs.best,
                    svm: LinearSvmConfig { lambda: gs.best_lambda, ..Default::default() },
                    ordering: protocol.ordering,
                };
                let model = train_pipeline(&cfg, &split.train)?;
                hyper_times.push(hyper_timer.secs());

                let test_timer = Timer::start();
                let err = model.error_on(&split.test);
                test_times.push(test_timer.secs());
                errors.push(err);
                sizes.push(model.transformer.total_size() as f64);
                degrees.push(model.transformer.avg_degree());
                spars.push(model.transformer.sparsity());
            }
            Method::KernelSvm => {
                let hyper_timer = Timer::start();
                let (best_cfg, _cv_err, _secs) = grid_search_kernel_svm(
                    &split.train,
                    &[2, 3, 4],
                    protocol.lambdas,
                    protocol.cv_folds,
                    protocol.seed + 100 + split_i as u64,
                    pool,
                )?;
                let svm =
                    PolyKernelSvm::fit(&split.train.x, &split.train.y, ds.n_classes, best_cfg)?;
                hyper_times.push(hyper_timer.secs());
                let test_timer = Timer::start();
                let err = error_rate(&svm.predict(&split.test.x), &split.test.y);
                test_times.push(test_timer.secs());
                errors.push(err);
                sizes.push(0.0);
                degrees.push(best_cfg.degree as f64);
                spars.push(0.0);
            }
        }
    }

    Ok(CellResult {
        method: method.name(),
        dataset: ds.name.clone(),
        error_mean: mean(&errors),
        error_std: std_dev(&errors),
        hyper_secs: mean(&hyper_times),
        test_secs: mean(&test_times),
        size: mean(&sizes),
        degree: mean(&degrees),
        spar: mean(&spars),
    })
}

/// Pretty-print a block of cells as a paper-style table.
pub fn format_table(cells: &[CellResult]) -> String {
    use crate::util::sci;
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:<10} {:>9} {:>11} {:>11} {:>9} {:>7} {:>6}\n",
        "method", "dataset", "err %", "hyper s", "test s", "|G|+|O|", "deg", "SPAR"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<22} {:<10} {:>9.2} {:>11} {:>11} {:>9.1} {:>7.2} {:>6.2}\n",
            c.method,
            c.dataset,
            c.error_mean * 100.0,
            sci(c.hyper_secs),
            sci(c.test_secs),
            c.size,
            c.degree,
            c.spar
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::oavi::OaviConfig;

    #[test]
    fn cell_runs_for_generator_method() {
        let ds = synthetic_dataset(240, 31);
        let protocol = Protocol {
            n_splits: 2,
            cv_folds: 2,
            psis: &[0.01],
            lambdas: &[1e-3],
            ..Default::default()
        };
        let pool = ThreadPool::new(2);
        let cell = run_cell(
            Method::Estimator(EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))),
            &ds,
            &protocol,
            &pool,
        )
        .unwrap();
        assert_eq!(cell.method, "CGAVI-IHB+SVM");
        assert!(cell.error_mean <= 0.5);
        assert!(cell.size > 0.0);
        assert!(cell.hyper_secs > 0.0);
        assert!(cell.degree >= 1.0);
    }

    #[test]
    fn cell_runs_for_kernel_svm() {
        let ds = synthetic_dataset(150, 32);
        let protocol = Protocol {
            n_splits: 1,
            cv_folds: 2,
            psis: &[0.01],
            lambdas: &[1e-3],
            ..Default::default()
        };
        let pool = ThreadPool::new(2);
        let cell = run_cell(Method::KernelSvm, &ds, &protocol, &pool).unwrap();
        assert_eq!(cell.method, "SVM");
        assert_eq!(cell.size, 0.0);
    }

    #[test]
    fn table_formatting_contains_rows() {
        let cell = CellResult {
            method: "X+SVM".into(),
            dataset: "toy".into(),
            error_mean: 0.0123,
            error_std: 0.001,
            hyper_secs: 3.1,
            test_secs: 0.0015,
            size: 28.8,
            degree: 2.09,
            spar: 0.41,
        };
        let t = format_table(&[cell]);
        assert!(t.contains("X+SVM"));
        assert!(t.contains("1.23"));
        assert!(t.contains("3.1e+00"));
    }
}

//! The classification pipeline of Algorithm 2: per-class generator
//! construction → (FT) feature transform → ℓ1 linear SVM, plus the
//! hyperparameter grid search (3-fold CV) and Table-3 style reporting.
//!
//! # Layering (store → backend → estimator → pipeline)
//!
//! This module sits at the top of the stack and is **algorithm-
//! agnostic**: it consumes only the
//! [`crate::estimator::VanishingIdealEstimator`] trait (built from a
//! typed [`EstimatorConfig`]) and the [`crate::estimator::FittedModel`]
//! objects it returns.  One generator method or another — OAVI variants,
//! ABM, VCA, or any future constructor — changes nothing here:
//!
//! * the data plane ([`crate::backend::ColumnStore`]) owns evaluation
//!   columns in row shards,
//! * a [`ComputeBackend`] executes the streaming kernels over it
//!   (native / sharded / PJRT),
//! * an estimator fits per-class models through that backend,
//! * this pipeline concatenates the per-class (FT) blocks and trains the
//!   ℓ1 SVM on them.
//!
//! Persistence for trained pipelines is the unified envelope in
//! [`crate::estimator::persist`].

pub mod gridsearch;
pub mod plan;
pub mod report;

use crate::backend::sharded::MIN_ROWS_PER_SHARD;
use crate::backend::{ComputeBackend, NativeBackend, ShardedBackend};
use crate::coordinator::pool::{Job, PoolHandle, ThreadPool};
use crate::data::Dataset;
use crate::error::{AviError, Result};
use crate::estimator::{EstimatorConfig, FittedModel, VanishingIdealEstimator};
use crate::linalg::dense::Matrix;
use crate::ordering::{order_features, FeatureOrdering};
use crate::svm::linear::{LinearSvm, LinearSvmConfig};

/// The union-of-classes feature transformer (Algorithm 2 Lines 1–9):
/// one fitted model per class, any estimator.
#[derive(Clone, Debug)]
pub struct FittedTransformer {
    pub method_name: String,
    pub per_class: Vec<Box<dyn FittedModel>>,
}

impl FittedTransformer {
    /// (FT): concatenate |g(x)| blocks of all classes → m × |G| features
    /// (native streaming backend).
    pub fn transform(&self, x: &Matrix) -> Matrix {
        self.transform_with(x, &NativeBackend)
    }

    /// (FT) through an explicit streaming backend (native / sharded /
    /// PJRT) — the serving path's intra-batch parallelism knob.  Each
    /// class writes its feature columns directly into its column range
    /// of the concatenated matrix (no intermediate per-class blocks, no
    /// row-by-row stitch).
    pub fn transform_with(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
        let total = self.n_generators();
        let mut out = Matrix::zeros(x.rows(), total);
        let mut off = 0;
        for c in &self.per_class {
            c.transform_into(x, backend, out.data_mut(), total, off);
            off += c.n_generators();
        }
        out
    }

    /// (FT) with **two-level parallelism** over a shared pool: per-class
    /// transforms fan out as outer jobs (the worker budget split once via
    /// [`PoolHandle::budget_split`]) and each job's [`ShardedBackend`]
    /// shard kernels are the inner axis.  The transform is per-row
    /// independent, so the result is bitwise identical to
    /// [`FittedTransformer::transform_with`] regardless of the split.
    pub fn transform_pooled(&self, x: &Matrix, pool: &PoolHandle) -> Result<Matrix> {
        let n_classes = self.per_class.len();
        let total = self.n_generators();
        let mut out = Matrix::zeros(x.rows(), total);
        if n_classes == 0 {
            return Ok(out);
        }
        let (_, inner) = pool.budget_split(n_classes);
        let jobs: Vec<Job<'_, Matrix>> = self
            .per_class
            .iter()
            .map(|c| {
                let handle = pool.clone();
                Box::new(move || {
                    let backend =
                        ShardedBackend::boxed_with_handle(handle, inner, MIN_ROWS_PER_SHARD);
                    c.transform_with(x, backend.as_ref())
                }) as Job<'_, Matrix>
            })
            .collect();
        // workers can't share &mut column ranges of one slab without
        // unsafe, so jobs return owned blocks and the stitch is a
        // block-level strided copy on the caller's thread
        let mut off = 0;
        for result in pool.try_run_all(jobs) {
            match result {
                Ok(block) => {
                    let g = block.cols();
                    for i in 0..x.rows() {
                        let base = i * total + off;
                        out.data_mut()[base..base + g].copy_from_slice(block.row(i));
                    }
                    off += g;
                }
                Err(panic_msg) => {
                    return Err(AviError::Coordinator(format!(
                        "per-class transform job panicked: {panic_msg}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Σ_i (|G^i| + |O^i|) — Table 3's |G|+|O| row.
    pub fn total_size(&self) -> usize {
        self.per_class.iter().map(|c| c.total_size()).sum()
    }

    /// Total number of generators |G| (feature dimension after (FT)).
    pub fn n_generators(&self) -> usize {
        self.per_class.iter().map(|c| c.n_generators()).sum()
    }

    /// Weighted average generator degree across classes.
    pub fn avg_degree(&self) -> f64 {
        let (mut s, mut n) = (0.0, 0usize);
        for c in &self.per_class {
            s += c.avg_degree() * c.n_generators() as f64;
            n += c.n_generators();
        }
        if n == 0 {
            0.0
        } else {
            s / n as f64
        }
    }

    /// Sum of the per-class raw fit counters — the pipeline-level view
    /// of the Table-3 attribution stats (panel passes/cols, cross-cache
    /// hits, AGD warm starts, solver work).  Counters add across
    /// classes; `inf_disabled_ihb` ORs; `degree_reached` takes the max.
    pub fn aggregate_stats(&self) -> crate::oavi::FitStats {
        let mut out = crate::oavi::FitStats::default();
        for c in &self.per_class {
            let s = &c.report().stats;
            out.oracle_calls += s.oracle_calls;
            out.ihb_solves += s.ihb_solves;
            out.solver_runs += s.solver_runs;
            out.solver_iters += s.solver_iters;
            out.warm_starts += s.warm_starts;
            out.wihb_resolves += s.wihb_resolves;
            out.gram_rebuilds += s.gram_rebuilds;
            out.inf_disabled_ihb |= s.inf_disabled_ihb;
            out.degree_reached = out.degree_reached.max(s.degree_reached);
            out.panel_passes += s.panel_passes;
            out.panel_cols += s.panel_cols;
            out.cross_cache_hits += s.cross_cache_hits;
        }
        out
    }

    /// (SPAR) pooled across classes (numerators/denominators pooled
    /// rather than averaging ratios).
    pub fn sparsity(&self) -> f64 {
        let (mut num, mut den) = (0.0, 0.0);
        for c in &self.per_class {
            let (z, t) = c.sparsity_pool();
            num += z;
            den += t;
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }
}

/// Fit the per-class models (Algorithm 2 Lines 1–5) through the
/// estimator trait — the single fit surface for every generator method.
pub fn fit_transformer(
    estimator: &dyn VanishingIdealEstimator,
    train: &Dataset,
    backend: &dyn ComputeBackend,
) -> Result<FittedTransformer> {
    let mut per_class = Vec::with_capacity(train.n_classes);
    for k in 0..train.n_classes {
        let xk = train.class_matrix(k);
        if xk.rows() == 0 {
            return Err(AviError::Data(format!("class {k} has no samples")));
        }
        per_class.push(estimator.fit(&xk, backend)?);
    }
    // the method name travels on the FitReport, not on a config enum
    let method_name = per_class
        .first()
        .map(|m| m.report().name().to_string())
        .unwrap_or_else(|| estimator.name());
    Ok(FittedTransformer { method_name, per_class })
}

/// [`fit_transformer`] with **two-level parallelism** over a shared
/// pool: the per-class fits are outer jobs and each job's
/// [`ShardedBackend`] shard kernels are the inner axis, the worker
/// budget split once via
/// [`crate::coordinator::pool::PoolHandle::budget_split`]
/// (`outer × inner ≤ workers`).  The `ComputeBackend` trait is `!Send`,
/// so each class job builds its own backend around the handle; fitted
/// models come back in class order (`FittedModel: Send`), so the result
/// is identical to the sequential fit through a backend with the same
/// shard sizing.
pub fn fit_transformer_pooled(
    config: &EstimatorConfig,
    train: &Dataset,
    pool: &PoolHandle,
) -> Result<FittedTransformer> {
    config.validate()?;
    let n_classes = train.n_classes;
    let (_, inner) = pool.budget_split(n_classes);
    let cfg = *config;
    let jobs: Vec<Job<'_, Result<Box<dyn FittedModel>>>> = (0..n_classes)
        .map(|k| {
            let handle = pool.clone();
            Box::new(move || {
                let xk = train.class_matrix(k);
                if xk.rows() == 0 {
                    return Err(AviError::Data(format!("class {k} has no samples")));
                }
                let backend =
                    ShardedBackend::boxed_with_handle(handle, inner, MIN_ROWS_PER_SHARD);
                cfg.build().fit(&xk, backend.as_ref())
            }) as Job<'_, Result<Box<dyn FittedModel>>>
        })
        .collect();
    let mut per_class = Vec::with_capacity(n_classes);
    for result in pool.try_run_all(jobs) {
        match result {
            Ok(fit) => per_class.push(fit?),
            Err(panic_msg) => {
                return Err(AviError::Coordinator(format!(
                    "per-class fit job panicked: {panic_msg}"
                )))
            }
        }
    }
    let method_name = per_class
        .first()
        .map(|m| m.report().name().to_string())
        .unwrap_or_else(|| config.name());
    Ok(FittedTransformer { method_name, per_class })
}

/// Full pipeline configuration.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub estimator: EstimatorConfig,
    pub svm: LinearSvmConfig,
    pub ordering: FeatureOrdering,
}

/// A trained pipeline: ordering permutation + transformer + SVM.
#[derive(Clone, Debug)]
pub struct PipelineModel {
    pub perm: Vec<usize>,
    pub transformer: FittedTransformer,
    pub svm: LinearSvm,
    pub n_classes: usize,
}

impl PipelineModel {
    /// Predict labels for raw (scaled) features (native backend).
    pub fn predict(&self, x: &Matrix) -> Vec<usize> {
        self.predict_with_backend(x, &NativeBackend)
    }

    /// Predict through an explicit streaming backend — lets the serving
    /// path run the (FT) transform sharded across cores.
    pub fn predict_with_backend(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Vec<usize> {
        let xp = permute_cols(x, &self.perm);
        let feats = self.transformer.transform_with(&xp, backend);
        self.svm.predict(&feats)
    }

    /// Labels **and** per-class decision scores through an explicit
    /// backend — the serving protocol's reply payload.  Labels are
    /// derived from the same decision vectors via
    /// [`LinearSvm::label_from_decision`], so the two can never disagree
    /// with [`PipelineModel::predict_with_backend`].
    pub fn predict_scores_with_backend(
        &self,
        x: &Matrix,
        backend: &dyn ComputeBackend,
    ) -> (Vec<usize>, Vec<Vec<f64>>) {
        let xp = permute_cols(x, &self.perm);
        let feats = self.transformer.transform_with(&xp, backend);
        let scores = self.svm.decision(&feats);
        let labels = scores.iter().map(|d| self.svm.label_from_decision(d)).collect();
        (labels, scores)
    }

    /// Classification error on a dataset.
    pub fn error_on(&self, ds: &Dataset) -> f64 {
        crate::svm::metrics::error_rate(&self.predict(&ds.x), &ds.y)
    }
}

/// Train the full Algorithm-2 pipeline.
pub fn train_pipeline(cfg: &PipelineConfig, train: &Dataset) -> Result<PipelineModel> {
    train_pipeline_with_backend(cfg, train, &NativeBackend)
}

/// Train with an explicit compute backend.
pub fn train_pipeline_with_backend(
    cfg: &PipelineConfig,
    train: &Dataset,
    backend: &dyn ComputeBackend,
) -> Result<PipelineModel> {
    cfg.estimator.validate()?;
    let estimator = cfg.estimator.build();
    let ordering = if estimator.is_monomial_aware() {
        cfg.ordering
    } else {
        FeatureOrdering::Native // VCA is data-driven already (§5)
    };
    let perm = order_features(&train.x, ordering);
    let ordered = train.permute_features(&perm);
    let transformer = fit_transformer(estimator.as_ref(), &ordered, backend)?;
    let feats = transformer.transform_with(&ordered.x, backend);
    let svm = LinearSvm::fit(&feats, &ordered.y, ordered.n_classes, cfg.svm)?;
    Ok(PipelineModel { perm, transformer, svm, n_classes: train.n_classes })
}

/// Train the full pipeline with two-level parallelism over `pool`:
/// per-class fits as outer jobs, shard kernels as the inner axis (see
/// [`fit_transformer_pooled`]), and the final (FT) transform sharded
/// across the whole worker budget.
pub fn train_pipeline_pooled(
    cfg: &PipelineConfig,
    train: &Dataset,
    pool: &ThreadPool,
) -> Result<PipelineModel> {
    cfg.estimator.validate()?;
    let ordering = if cfg.estimator.is_monomial_aware() {
        cfg.ordering
    } else {
        FeatureOrdering::Native // VCA is data-driven already (§5)
    };
    let perm = order_features(&train.x, ordering);
    let ordered = train.permute_features(&perm);
    let handle = pool.handle();
    let transformer = fit_transformer_pooled(&cfg.estimator, &ordered, &handle)?;
    // the final (FT) pass fans per-class blocks out as outer pool jobs,
    // with shard kernels as the inner axis — same split as the fit
    let feats = transformer.transform_pooled(&ordered.x, &handle)?;
    let svm = LinearSvm::fit(&feats, &ordered.y, ordered.n_classes, cfg.svm)?;
    Ok(PipelineModel { perm, transformer, svm, n_classes: train.n_classes })
}

fn permute_cols(x: &Matrix, perm: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), perm.len());
    for i in 0..x.rows() {
        for (new_j, &old_j) in perm.iter().enumerate() {
            out.set(i, new_j, x.get(i, old_j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::oavi::OaviConfig;

    fn small_synth() -> Dataset {
        synthetic_dataset(600, 9)
    }

    #[test]
    fn oavi_pipeline_beats_chance_on_synthetic() {
        let ds = small_synth();
        let split = crate::data::splits::train_test_split(&ds, 0.6, 1);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.005)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        let model = train_pipeline(&cfg, &split.train).unwrap();
        let err = model.error_on(&split.test);
        assert!(err < 0.25, "test error {err}");
        assert!(model.transformer.n_generators() > 0);
    }

    #[test]
    fn all_estimators_run_end_to_end() {
        let ds = small_synth().head(300);
        let split = crate::data::splits::train_test_split(&ds, 0.6, 2);
        for estimator in EstimatorConfig::battery(0.01) {
            let cfg = PipelineConfig {
                estimator,
                svm: LinearSvmConfig::default(),
                ordering: FeatureOrdering::Pearson,
            };
            let model = train_pipeline(&cfg, &split.train).unwrap();
            let err = model.error_on(&split.test);
            assert!(err <= 0.5, "{}: error {err}", estimator.name());
            assert!(model.transformer.total_size() > 0);
            assert_eq!(model.transformer.method_name, estimator.name());
        }
    }

    #[test]
    fn transform_concatenates_class_blocks() {
        let ds = small_synth().head(200);
        let est = EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01));
        let t = fit_transformer(est.build().as_ref(), &ds, &NativeBackend).unwrap();
        let feats = t.transform(&ds.x);
        assert_eq!(feats.cols(), t.n_generators());
        assert_eq!(feats.rows(), 200);
        assert_eq!(t.per_class.len(), 2);
    }

    #[test]
    fn stats_are_finite_and_consistent() {
        let ds = small_synth().head(300);
        let est = EstimatorConfig::Oavi(OaviConfig::bpcgavi_wihb(0.01));
        let t = fit_transformer(est.build().as_ref(), &ds, &NativeBackend).unwrap();
        assert!(t.avg_degree() >= 1.0);
        assert!((0.0..=1.0).contains(&t.sparsity()));
        assert!(t.total_size() >= t.n_generators());
    }

    #[test]
    fn pooled_per_class_fit_matches_sequential_on_small_data() {
        // small m ⇒ preferred_shards = 1 on every backend ⇒ the pooled
        // two-level fit is arithmetically identical to the native one
        let ds = small_synth().head(300);
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        let seq = train_pipeline(&cfg, &ds).unwrap();
        let pool = ThreadPool::new(4);
        let par = train_pipeline_pooled(&cfg, &ds, &pool).unwrap();
        assert_eq!(seq.perm, par.perm);
        assert_eq!(seq.transformer.method_name, par.transformer.method_name);
        assert_eq!(seq.transformer.n_generators(), par.transformer.n_generators());
        assert_eq!(seq.predict(&ds.x), par.predict(&ds.x));
    }

    #[test]
    fn pooled_fit_transformer_reports_empty_class() {
        let mut ds = small_synth().head(100);
        ds.n_classes += 1; // last class has no samples
        let pool = ThreadPool::new(2);
        let err = fit_transformer_pooled(
            &EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01)),
            &ds,
            &pool.handle(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn pooled_transform_is_bitwise_identical_to_sequential() {
        let ds = small_synth().head(250);
        let est = EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01));
        let t = fit_transformer(est.build().as_ref(), &ds, &NativeBackend).unwrap();
        let seq = t.transform_with(&ds.x, &NativeBackend);
        let pool = ThreadPool::new(4);
        let par = t.transform_pooled(&ds.x, &pool.handle()).unwrap();
        let seq_bits: Vec<u64> = seq.data().iter().map(|v| v.to_bits()).collect();
        let par_bits: Vec<u64> = par.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(seq_bits, par_bits);
    }

    #[test]
    fn cloned_transformer_transforms_identically() {
        let ds = small_synth().head(150);
        let est = EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01));
        let t = fit_transformer(est.build().as_ref(), &ds, &NativeBackend).unwrap();
        let t2 = t.clone();
        assert_eq!(t.transform(&ds.x).data(), t2.transform(&ds.x).data());
    }
}

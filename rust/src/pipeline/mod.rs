//! The classification pipeline of Algorithm 2: per-class generator
//! construction → (FT) feature transform → ℓ1 linear SVM, plus the
//! hyperparameter grid search (3-fold CV) and Table-3 style reporting.

pub mod gridsearch;
pub mod persist;
pub mod report;

use crate::backend::{ComputeBackend, NativeBackend};
use crate::baselines::abm::{Abm, AbmConfig};
use crate::baselines::vca::{Vca, VcaConfig, VcaModel};
use crate::data::Dataset;
use crate::error::{AviError, Result};
use crate::linalg::dense::Matrix;
use crate::oavi::{Oavi, OaviConfig};
use crate::ordering::{order_features, FeatureOrdering};
use crate::poly::poly::GeneratorSet;
use crate::svm::linear::{LinearSvm, LinearSvmConfig};

/// Which generator-constructing algorithm the pipeline uses.
#[derive(Clone, Copy, Debug)]
pub enum GeneratorMethod {
    Oavi(OaviConfig),
    Abm(AbmConfig),
    Vca(VcaConfig),
}

impl GeneratorMethod {
    /// The paper's method name (CGAVI-IHB, ABM, VCA, …).
    pub fn name(&self) -> String {
        match self {
            GeneratorMethod::Oavi(cfg) => cfg.name(),
            GeneratorMethod::Abm(_) => "ABM".into(),
            GeneratorMethod::Vca(_) => "VCA".into(),
        }
    }

    /// Same method with a different ψ (grid search).
    pub fn with_psi(&self, psi: f64) -> GeneratorMethod {
        match *self {
            GeneratorMethod::Oavi(mut cfg) => {
                cfg.psi = psi;
                GeneratorMethod::Oavi(cfg)
            }
            GeneratorMethod::Abm(mut cfg) => {
                cfg.psi = psi;
                GeneratorMethod::Abm(cfg)
            }
            GeneratorMethod::Vca(mut cfg) => {
                cfg.psi = psi;
                GeneratorMethod::Vca(cfg)
            }
        }
    }

    /// Monomial-aware methods need the Pearson ordering; VCA is agnostic.
    pub fn is_monomial_aware(&self) -> bool {
        !matches!(self, GeneratorMethod::Vca(_))
    }
}

/// Per-class fitted generator model.
#[derive(Clone, Debug)]
pub enum ClassModel {
    MonomialAware(GeneratorSet),
    Vca(VcaModel),
}

impl ClassModel {
    pub fn n_generators(&self) -> usize {
        match self {
            ClassModel::MonomialAware(gs) => gs.generators.len(),
            ClassModel::Vca(v) => v.n_generators(),
        }
    }

    pub fn total_size(&self) -> usize {
        match self {
            ClassModel::MonomialAware(gs) => gs.total_size(),
            ClassModel::Vca(v) => v.total_size(),
        }
    }

    fn transform_with(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
        match self {
            ClassModel::MonomialAware(gs) => gs.transform_with(x, backend),
            // VCA evaluates its polynomial DAG (no A·C+U form), so the
            // backend choice does not apply to it
            ClassModel::Vca(v) => v.transform(x),
        }
    }
}

/// The union-of-classes feature transformer (Algorithm 2 Lines 1–9).
#[derive(Clone, Debug)]
pub struct FittedTransformer {
    pub method_name: String,
    pub per_class: Vec<ClassModel>,
}

impl FittedTransformer {
    /// (FT): concatenate |g(x)| blocks of all classes → m × |G| features
    /// (native streaming backend).
    pub fn transform(&self, x: &Matrix) -> Matrix {
        self.transform_with(x, &NativeBackend)
    }

    /// (FT) through an explicit streaming backend (native / sharded /
    /// PJRT) — the serving path's intra-batch parallelism knob.
    pub fn transform_with(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Matrix {
        let blocks: Vec<Matrix> =
            self.per_class.iter().map(|c| c.transform_with(x, backend)).collect();
        let total: usize = blocks.iter().map(|b| b.cols()).sum();
        let mut out = Matrix::zeros(x.rows(), total);
        let mut off = 0;
        for b in &blocks {
            for i in 0..x.rows() {
                let dst = out.row_mut(i);
                dst[off..off + b.cols()].copy_from_slice(b.row(i));
            }
            off += b.cols();
        }
        out
    }

    /// Σ_i (|G^i| + |O^i|) — Table 3's |G|+|O| row.
    pub fn total_size(&self) -> usize {
        self.per_class.iter().map(|c| c.total_size()).sum()
    }

    /// Total number of generators |G| (feature dimension after (FT)).
    pub fn n_generators(&self) -> usize {
        self.per_class.iter().map(|c| c.n_generators()).sum()
    }

    /// Weighted average generator degree across classes.
    pub fn avg_degree(&self) -> f64 {
        let (mut s, mut n) = (0.0, 0usize);
        for c in &self.per_class {
            match c {
                ClassModel::MonomialAware(gs) => {
                    s += gs.avg_degree() * gs.generators.len() as f64;
                    n += gs.generators.len();
                }
                ClassModel::Vca(v) => {
                    s += v.avg_degree() * v.n_generators() as f64;
                    n += v.n_generators();
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            s / n as f64
        }
    }

    /// (SPAR) pooled across classes.
    pub fn sparsity(&self) -> f64 {
        // pool numerators/denominators rather than averaging ratios
        let mut num = 0.0;
        let mut den = 0.0;
        for c in &self.per_class {
            match c {
                ClassModel::MonomialAware(gs) => {
                    for g in &gs.generators {
                        num += g.n_zero_coeffs() as f64;
                        den += g.n_coeffs() as f64;
                    }
                }
                ClassModel::Vca(v) => {
                    // VCA's SPAR is already a pooled ratio; weight by its size
                    let ge = v.n_generators().max(1) as f64;
                    num += v.sparsity() * ge;
                    den += ge;
                }
            }
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }
}

/// Fit the per-class generator models (Algorithm 2 Lines 1–5).
pub fn fit_transformer(
    method: &GeneratorMethod,
    train: &Dataset,
    backend: &dyn ComputeBackend,
) -> Result<FittedTransformer> {
    let mut per_class = Vec::with_capacity(train.n_classes);
    for k in 0..train.n_classes {
        let xk = train.class_matrix(k);
        if xk.rows() == 0 {
            return Err(AviError::Data(format!("class {k} has no samples")));
        }
        let model = match method {
            GeneratorMethod::Oavi(cfg) => ClassModel::MonomialAware(
                Oavi::new(*cfg).fit_with_backend(&xk, backend)?.generator_set(),
            ),
            GeneratorMethod::Abm(cfg) => ClassModel::MonomialAware(
                Abm::new(*cfg).fit_with_backend(&xk, backend)?.generator_set(),
            ),
            GeneratorMethod::Vca(cfg) => ClassModel::Vca(Vca::new(*cfg).fit(&xk)?),
        };
        per_class.push(model);
    }
    Ok(FittedTransformer { method_name: method.name(), per_class })
}

/// Full pipeline configuration.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub method: GeneratorMethod,
    pub svm: LinearSvmConfig,
    pub ordering: FeatureOrdering,
}

/// A trained pipeline: ordering permutation + transformer + SVM.
#[derive(Clone, Debug)]
pub struct PipelineModel {
    pub perm: Vec<usize>,
    pub transformer: FittedTransformer,
    pub svm: LinearSvm,
    pub n_classes: usize,
}

impl PipelineModel {
    /// Predict labels for raw (scaled) features (native backend).
    pub fn predict(&self, x: &Matrix) -> Vec<usize> {
        self.predict_with_backend(x, &NativeBackend)
    }

    /// Predict through an explicit streaming backend — lets the serving
    /// path run the (FT) transform sharded across cores.
    pub fn predict_with_backend(&self, x: &Matrix, backend: &dyn ComputeBackend) -> Vec<usize> {
        let xp = permute_cols(x, &self.perm);
        let feats = self.transformer.transform_with(&xp, backend);
        self.svm.predict(&feats)
    }

    /// Classification error on a dataset.
    pub fn error_on(&self, ds: &Dataset) -> f64 {
        crate::svm::metrics::error_rate(&self.predict(&ds.x), &ds.y)
    }
}

/// Train the full Algorithm-2 pipeline.
pub fn train_pipeline(cfg: &PipelineConfig, train: &Dataset) -> Result<PipelineModel> {
    train_pipeline_with_backend(cfg, train, &NativeBackend)
}

/// Train with an explicit compute backend.
pub fn train_pipeline_with_backend(
    cfg: &PipelineConfig,
    train: &Dataset,
    backend: &dyn ComputeBackend,
) -> Result<PipelineModel> {
    let ordering = if cfg.method.is_monomial_aware() {
        cfg.ordering
    } else {
        FeatureOrdering::Native // VCA is data-driven already (§5)
    };
    let perm = order_features(&train.x, ordering);
    let ordered = train.permute_features(&perm);
    let transformer = fit_transformer(&cfg.method, &ordered, backend)?;
    let feats = transformer.transform_with(&ordered.x, backend);
    let svm = LinearSvm::fit(&feats, &ordered.y, ordered.n_classes, cfg.svm)?;
    Ok(PipelineModel { perm, transformer, svm, n_classes: train.n_classes })
}

fn permute_cols(x: &Matrix, perm: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), perm.len());
    for i in 0..x.rows() {
        for (new_j, &old_j) in perm.iter().enumerate() {
            out.set(i, new_j, x.get(i, old_j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;

    fn small_synth() -> Dataset {
        synthetic_dataset(600, 9)
    }

    #[test]
    fn oavi_pipeline_beats_chance_on_synthetic() {
        let ds = small_synth();
        let split = crate::data::splits::train_test_split(&ds, 0.6, 1);
        let cfg = PipelineConfig {
            method: GeneratorMethod::Oavi(OaviConfig::cgavi_ihb(0.005)),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        let model = train_pipeline(&cfg, &split.train).unwrap();
        let err = model.error_on(&split.test);
        assert!(err < 0.25, "test error {err}");
        assert!(model.transformer.n_generators() > 0);
    }

    #[test]
    fn all_methods_run_end_to_end() {
        let ds = small_synth().head(300);
        let split = crate::data::splits::train_test_split(&ds, 0.6, 2);
        for method in [
            GeneratorMethod::Oavi(OaviConfig::cgavi_ihb(0.01)),
            GeneratorMethod::Oavi(OaviConfig::bpcgavi_wihb(0.01)),
            GeneratorMethod::Abm(AbmConfig::new(0.01)),
            GeneratorMethod::Vca(VcaConfig::new(0.01)),
        ] {
            let cfg = PipelineConfig {
                method,
                svm: LinearSvmConfig::default(),
                ordering: FeatureOrdering::Pearson,
            };
            let model = train_pipeline(&cfg, &split.train).unwrap();
            let err = model.error_on(&split.test);
            assert!(err <= 0.5, "{}: error {err}", method.name());
            assert!(model.transformer.total_size() > 0);
        }
    }

    #[test]
    fn transform_concatenates_class_blocks() {
        let ds = small_synth().head(200);
        let method = GeneratorMethod::Oavi(OaviConfig::cgavi_ihb(0.01));
        let t = fit_transformer(&method, &ds, &NativeBackend).unwrap();
        let feats = t.transform(&ds.x);
        assert_eq!(feats.cols(), t.n_generators());
        assert_eq!(feats.rows(), 200);
        assert_eq!(t.per_class.len(), 2);
    }

    #[test]
    fn with_psi_rewrites_psi_everywhere() {
        let m = GeneratorMethod::Oavi(OaviConfig::cgavi_ihb(0.1)).with_psi(0.02);
        match m {
            GeneratorMethod::Oavi(cfg) => assert_eq!(cfg.psi, 0.02),
            _ => unreachable!(),
        }
        let m = GeneratorMethod::Vca(VcaConfig::new(0.1)).with_psi(0.3);
        match m {
            GeneratorMethod::Vca(cfg) => assert_eq!(cfg.psi, 0.3),
            _ => unreachable!(),
        }
    }

    #[test]
    fn stats_are_finite_and_consistent() {
        let ds = small_synth().head(300);
        let method = GeneratorMethod::Oavi(OaviConfig::bpcgavi_wihb(0.01));
        let t = fit_transformer(&method, &ds, &NativeBackend).unwrap();
        assert!(t.avg_degree() >= 1.0);
        assert!((0.0..=1.0).contains(&t.sparsity()));
        assert!(t.total_size() >= t.n_generators());
    }
}

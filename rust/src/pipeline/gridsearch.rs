//! Hyperparameter grid search with k-fold CV (paper §6.2: 3-fold CV over
//! the vanishing parameter ψ and the SVM's ℓ1 coefficient).

use crate::backend::ShardedBackend;
use crate::coordinator::pool::ThreadPool;
use crate::data::splits::kfold_indices;
use crate::data::Dataset;
use crate::error::Result;
use crate::ordering::FeatureOrdering;
use crate::pipeline::{train_pipeline_with_backend, GeneratorMethod, PipelineConfig};
use crate::svm::kernel::{PolyKernelConfig, PolyKernelSvm};
use crate::svm::linear::LinearSvmConfig;
use crate::svm::metrics::error_rate;
use crate::util::timer::Timer;

/// Default ψ grid (log-spaced around the paper's 0.005 working point).
pub const PSI_GRID: &[f64] = &[0.05, 0.01, 0.005, 0.001];
/// Default SVM ℓ1 grid.
pub const LAMBDA_GRID: &[f64] = &[1e-2, 1e-3, 1e-4];

/// Result of a grid search.
#[derive(Clone, Debug)]
pub struct GridSearchResult {
    pub best_psi: f64,
    pub best_lambda: f64,
    pub best_cv_error: f64,
    /// wall-clock of the whole search (Table 3 "Time hyper.", together
    /// with the final refit).
    pub search_secs: f64,
    /// (psi, lambda, cv_error) for every grid point.
    pub table: Vec<(f64, f64, f64)>,
}

/// Cross-validated grid search for a generator method + linear SVM.
/// `pool` parallelizes grid points across worker threads (single-threaded
/// within each fit — the seed behavior).
pub fn grid_search(
    method: &GeneratorMethod,
    ordering: FeatureOrdering,
    train: &Dataset,
    psis: &[f64],
    lambdas: &[f64],
    folds: usize,
    seed: u64,
    pool: &ThreadPool,
) -> Result<GridSearchResult> {
    grid_search_sharded(method, ordering, train, psis, lambdas, folds, seed, pool, 1)
}

/// [`grid_search`] with an **intra-fit** parallelism knob on top of the
/// job-level pool: each grid-point job fits through a [`ShardedBackend`]
/// with `intra_shards` workers.  Use it when the grid is smaller than the
/// machine (few grid points, many cores) — the two levels multiply.
#[allow(clippy::too_many_arguments)]
pub fn grid_search_sharded(
    method: &GeneratorMethod,
    ordering: FeatureOrdering,
    train: &Dataset,
    psis: &[f64],
    lambdas: &[f64],
    folds: usize,
    seed: u64,
    pool: &ThreadPool,
    intra_shards: usize,
) -> Result<GridSearchResult> {
    let timer = Timer::start();
    let fold_idx = kfold_indices(train.len(), folds, seed);
    // pre-materialize fold datasets once
    let fold_data: Vec<(Dataset, Dataset)> = fold_idx
        .iter()
        .map(|(tr, va)| (train.subset(tr), train.subset(va)))
        .collect();

    // one job per (psi, lambda): CV error averaged over folds
    let mut jobs: Vec<Box<dyn FnOnce() -> (f64, f64, f64) + Send>> = Vec::new();
    for &psi in psis {
        for &lambda in lambdas {
            let method = method.with_psi(psi);
            let fold_data = fold_data.clone();
            jobs.push(Box::new(move || {
                // one backend per job: the ComputeBackend trait is !Send,
                // so each worker constructs its own (see backend/mod.rs)
                let backend = ShardedBackend::boxed_for(intra_shards);
                let mut errs = Vec::with_capacity(fold_data.len());
                for (tr, va) in &fold_data {
                    let cfg = PipelineConfig {
                        method,
                        svm: LinearSvmConfig { lambda, ..Default::default() },
                        ordering,
                    };
                    match train_pipeline_with_backend(&cfg, tr, backend.as_ref()) {
                        Ok(model) => errs.push(model.error_on(va)),
                        Err(_) => errs.push(1.0), // failed config = worst error
                    }
                }
                (psi, lambda, crate::util::mean(&errs))
            }));
        }
    }
    let table = pool.run_all(jobs);

    let (mut best_psi, mut best_lambda, mut best_err) = (psis[0], lambdas[0], f64::INFINITY);
    for &(psi, lambda, err) in &table {
        if err < best_err {
            best_err = err;
            best_psi = psi;
            best_lambda = lambda;
        }
    }
    Ok(GridSearchResult {
        best_psi,
        best_lambda,
        best_cv_error: best_err,
        search_secs: timer.secs(),
        table,
    })
}

/// Grid search for the polynomial-kernel SVM baseline (degree × λ).
pub fn grid_search_kernel_svm(
    train: &Dataset,
    degrees: &[u32],
    lambdas: &[f64],
    folds: usize,
    seed: u64,
    pool: &ThreadPool,
) -> Result<(PolyKernelConfig, f64, f64)> {
    let timer = Timer::start();
    let fold_idx = kfold_indices(train.len(), folds, seed);
    let fold_data: Vec<(Dataset, Dataset)> = fold_idx
        .iter()
        .map(|(tr, va)| (train.subset(tr), train.subset(va)))
        .collect();

    let mut jobs: Vec<Box<dyn FnOnce() -> (u32, f64, f64) + Send>> = Vec::new();
    for &degree in degrees {
        for &lambda in lambdas {
            let fold_data = fold_data.clone();
            jobs.push(Box::new(move || {
                let mut errs = Vec::new();
                for (tr, va) in &fold_data {
                    let cfg = PolyKernelConfig { degree, lambda, ..Default::default() };
                    match PolyKernelSvm::fit(&tr.x, &tr.y, tr.n_classes, cfg) {
                        Ok(svm) => errs.push(error_rate(&svm.predict(&va.x), &va.y)),
                        Err(_) => errs.push(1.0),
                    }
                }
                (degree, lambda, crate::util::mean(&errs))
            }));
        }
    }
    let table = pool.run_all(jobs);
    let mut best = (degrees[0], lambdas[0], f64::INFINITY);
    for &(d, l, e) in &table {
        if e < best.2 {
            best = (d, l, e);
        }
    }
    Ok((
        PolyKernelConfig { degree: best.0, lambda: best.1, ..Default::default() },
        best.2,
        timer.secs(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::oavi::OaviConfig;

    #[test]
    fn grid_search_selects_reasonable_psi() {
        let ds = synthetic_dataset(400, 3);
        let pool = ThreadPool::new(2);
        let res = grid_search(
            &GeneratorMethod::Oavi(OaviConfig::cgavi_ihb(0.01)),
            FeatureOrdering::Pearson,
            &ds,
            &[0.05, 0.005],
            &[1e-3],
            3,
            7,
            &pool,
        )
        .unwrap();
        assert_eq!(res.table.len(), 2);
        assert!(res.best_cv_error <= 0.5);
        assert!(res.table.iter().any(|&(p, _, _)| p == res.best_psi));
        assert!(res.search_secs > 0.0);
    }

    #[test]
    fn sharded_grid_search_runs_and_agrees_on_small_fits() {
        // small m ⇒ preferred_shards = 1 ⇒ identical arithmetic to the
        // single-threaded search
        let ds = synthetic_dataset(300, 8);
        let pool = ThreadPool::new(2);
        let base = grid_search(
            &GeneratorMethod::Oavi(OaviConfig::cgavi_ihb(0.01)),
            FeatureOrdering::Pearson,
            &ds,
            &[0.05],
            &[1e-3],
            3,
            7,
            &pool,
        )
        .unwrap();
        let sharded = grid_search_sharded(
            &GeneratorMethod::Oavi(OaviConfig::cgavi_ihb(0.01)),
            FeatureOrdering::Pearson,
            &ds,
            &[0.05],
            &[1e-3],
            3,
            7,
            &pool,
            2,
        )
        .unwrap();
        assert_eq!(base.table.len(), sharded.table.len());
        assert_eq!(base.best_cv_error, sharded.best_cv_error);
    }

    #[test]
    fn kernel_grid_runs() {
        let ds = synthetic_dataset(200, 4);
        let pool = ThreadPool::new(2);
        let (cfg, err, secs) =
            grid_search_kernel_svm(&ds, &[2, 3], &[1e-3], 3, 5, &pool).unwrap();
        assert!(cfg.degree == 2 || cfg.degree == 3);
        assert!(err <= 0.6);
        assert!(secs > 0.0);
    }
}

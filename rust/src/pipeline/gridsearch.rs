//! Hyperparameter grid search with k-fold CV (paper §6.2: 3-fold CV over
//! the vanishing parameter ψ and the SVM's ℓ1 coefficient), over **any
//! set of estimators**: the grid is estimator × ψ × τ × λ, so a single
//! search can race CGAVI-IHB against ABM and VCA (mixed-method model
//! selection) with one deduplicated loop instead of per-algorithm
//! near-duplicates.  Grids are **estimator-aware**: an empty `psis` /
//! `lambdas` argument means "each estimator's own
//! [`crate::estimator::HyperGrid`]" (per-method ψ and λ ranges, with the
//! τ axis joining for the ℓ1-constrained OAVI variants), while explicit
//! grids reproduce the classic shared sweep with τ pinned.
//!
//! Parallelism is **two-level** over one persistent pool: grid-point
//! jobs are the outer axis and each job's `ShardedBackend` shard kernels
//! are the inner axis, both drawing from the same
//! [`crate::coordinator::pool::PoolHandle`] with the worker budget split
//! once (`outer × inner ≤ workers`, see [`GridParallelism`]).

use crate::backend::sharded::MIN_ROWS_PER_SHARD;
use crate::backend::{ComputeBackend, PinnedShards, ShardedBackend};
use crate::coordinator::pool::ThreadPool;
use crate::data::splits::kfold_indices;
use crate::data::Dataset;
use crate::error::{AviError, Result};
use crate::estimator::EstimatorConfig;
use crate::ordering::FeatureOrdering;
use crate::pipeline::{train_pipeline_with_backend, PipelineConfig};
use crate::svm::kernel::{PolyKernelConfig, PolyKernelSvm};
use crate::svm::linear::LinearSvmConfig;
use crate::svm::metrics::error_rate;
use crate::util::timer::Timer;

/// Default ψ and λ grids — re-exported from the estimator layer, where
/// [`crate::estimator::VanishingIdealEstimator::hyper_grid`] defaults to
/// them (and overrides them per method).
pub use crate::estimator::{LAMBDA_GRID, PSI_GRID};

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct GridPoint {
    /// Method name of the winner's [`crate::estimator::FitReport`] (falls
    /// back to the config name when every fold failed).
    pub name: String,
    pub estimator: EstimatorConfig,
    pub psi: f64,
    /// ℓ1 bound swept for constrained methods in per-method grid mode
    /// (`None` when τ stayed at the config default / does not apply).
    pub tau: Option<f64>,
    pub lambda: f64,
    pub cv_error: f64,
}

/// Result of a grid search.
#[derive(Clone, Debug)]
pub struct GridSearchResult {
    /// Winning estimator config with the best ψ (and τ) already applied.
    pub best: EstimatorConfig,
    /// The winner's fitted method name (via `FitReport::name()`).
    pub best_name: String,
    pub best_psi: f64,
    pub best_tau: Option<f64>,
    pub best_lambda: f64,
    pub best_cv_error: f64,
    /// wall-clock of the whole search (Table 3 "Time hyper.", together
    /// with the final refit).
    pub search_secs: f64,
    /// every evaluated grid point, in submission order.
    pub table: Vec<GridPoint>,
}

/// How a grid search spends the pool's worker budget across the two
/// parallelism levels (outer grid-point jobs × inner shard kernels).
#[derive(Clone, Copy, Debug, Default)]
pub struct GridParallelism {
    /// Inner (shard) worker budget each grid-point job fits through.
    /// `0` = automatic: [`crate::coordinator::pool::PoolHandle::budget_split`]
    /// over the realized grid size, so `outer × inner ≤ workers`.
    /// `1` = native single-threaded fits (the [`grid_search`] default).
    pub intra_workers: usize,
    /// Pin every fit's [`crate::backend::ColumnStore`] shard count
    /// (reproducibility/parity knob — results are deterministic per
    /// shard count, so pinning makes runs comparable across backends).
    pub pin_store_shards: Option<usize>,
}

impl GridParallelism {
    /// Automatic budget split (`outer × inner ≤ workers`), no pinning.
    pub fn auto() -> Self {
        GridParallelism { intra_workers: 0, pin_store_shards: None }
    }
}

/// Cross-validated grid search over estimator × ψ × λ with a linear SVM.
/// `pool` parallelizes grid points across worker threads (single-threaded
/// within each fit).  An empty `psis` slice means "each estimator's own
/// [`crate::estimator::VanishingIdealEstimator::hyper_grid`]".
#[allow(clippy::too_many_arguments)]
pub fn grid_search(
    estimators: &[EstimatorConfig],
    ordering: FeatureOrdering,
    train: &Dataset,
    psis: &[f64],
    lambdas: &[f64],
    folds: usize,
    seed: u64,
    pool: &ThreadPool,
) -> Result<GridSearchResult> {
    let par = GridParallelism { intra_workers: 1, pin_store_shards: None };
    grid_search_two_level(estimators, ordering, train, psis, lambdas, folds, seed, pool, par)
}

/// Deprecated alias for [`grid_search_two_level`] with an explicit
/// `intra_shards` inner budget and no shard pinning — kept for the PR-1
/// call sites; new code should pass a [`GridParallelism`] (or use
/// [`GridParallelism::auto`] for the budget split).
#[allow(clippy::too_many_arguments)]
pub fn grid_search_sharded(
    estimators: &[EstimatorConfig],
    ordering: FeatureOrdering,
    train: &Dataset,
    psis: &[f64],
    lambdas: &[f64],
    folds: usize,
    seed: u64,
    pool: &ThreadPool,
    intra_shards: usize,
) -> Result<GridSearchResult> {
    let par = GridParallelism { intra_workers: intra_shards.max(1), pin_store_shards: None };
    grid_search_two_level(estimators, ordering, train, psis, lambdas, folds, seed, pool, par)
}

/// Two-level grid search: grid-point jobs (outer axis) and each job's
/// [`ShardedBackend`] shard kernels (inner axis) draw from the **same**
/// pool via shared [`crate::coordinator::pool::PoolHandle`]s — no
/// per-job pool construction, and the worker budget is split once
/// (`outer × inner ≤ workers`) instead of oversubscribing.
#[allow(clippy::too_many_arguments)]
pub fn grid_search_two_level(
    estimators: &[EstimatorConfig],
    ordering: FeatureOrdering,
    train: &Dataset,
    psis: &[f64],
    lambdas: &[f64],
    folds: usize,
    seed: u64,
    pool: &ThreadPool,
    par: GridParallelism,
) -> Result<GridSearchResult> {
    if estimators.is_empty() {
        return Err(AviError::Config("grid_search: no estimators given".into()));
    }
    let timer = Timer::start();
    let fold_idx = kfold_indices(train.len(), folds, seed);
    // pre-materialize fold datasets once
    let fold_data: Vec<(Dataset, Dataset)> = fold_idx
        .iter()
        .map(|(tr, va)| (train.subset(tr), train.subset(va)))
        .collect();

    // materialize the grid first so the budget split sees its true size.
    // Empty `psis` / `lambdas` mean "each estimator's own hyper_grid()":
    // per-method ψ and λ ranges, with the τ axis joining for the
    // ℓ1-constrained methods (an explicit ψ grid reproduces the classic
    // estimator × ψ × λ sweep with τ pinned at the config value).
    let mut points: Vec<(EstimatorConfig, f64, Option<f64>, f64)> = Vec::new();
    for &base in estimators {
        let grid = base.build().hyper_grid();
        let psi_grid: Vec<f64> =
            if psis.is_empty() { grid.psis.to_vec() } else { psis.to_vec() };
        let lambda_grid: Vec<f64> =
            if lambdas.is_empty() { grid.lambdas.to_vec() } else { lambdas.to_vec() };
        let tau_grid: Vec<Option<f64>> = if psis.is_empty() && !grid.taus.is_empty() {
            grid.taus.iter().map(|&t| Some(t)).collect()
        } else {
            vec![None]
        };
        for &psi in &psi_grid {
            for &tau in &tau_grid {
                for &lambda in &lambda_grid {
                    let mut cfg = base.with_psi(psi);
                    if let Some(t) = tau {
                        cfg = cfg.with_tau(t);
                    }
                    points.push((cfg, psi, tau, lambda));
                }
            }
        }
    }
    if points.is_empty() {
        return Err(AviError::Config("grid_search: empty ψ/λ grid".into()));
    }
    let handle = pool.handle();
    let intra = if par.intra_workers == 0 {
        handle.budget_split(points.len()).1
    } else {
        par.intra_workers
    };
    let pin = par.pin_store_shards;

    // one job per (estimator, psi, lambda): CV error averaged over folds
    let mut jobs: Vec<Box<dyn FnOnce() -> GridPoint + Send>> = Vec::new();
    for (estimator, psi, tau, lambda) in points {
        let fold_data = fold_data.clone();
        let handle = handle.clone();
        jobs.push(Box::new(move || {
            // one backend per job: the ComputeBackend trait is !Send, so
            // each job constructs its own around the shared pool handle
            let backend = ShardedBackend::boxed_with_handle(handle, intra, MIN_ROWS_PER_SHARD);
            let backend: Box<dyn ComputeBackend> = match pin {
                Some(shards) => Box::new(PinnedShards::new(backend, shards)),
                None => backend,
            };
            let mut errs = Vec::with_capacity(fold_data.len());
            let mut fitted_name: Option<String> = None;
            for (tr, va) in &fold_data {
                let cfg = PipelineConfig {
                    estimator,
                    svm: LinearSvmConfig { lambda, ..Default::default() },
                    ordering,
                };
                match train_pipeline_with_backend(&cfg, tr, backend.as_ref()) {
                    Ok(model) => {
                        if fitted_name.is_none() {
                            // FitReport name, surfaced via the transformer
                            fitted_name = Some(model.transformer.method_name.clone());
                        }
                        errs.push(model.error_on(va));
                    }
                    Err(_) => errs.push(1.0), // failed config = worst error
                }
            }
            GridPoint {
                name: fitted_name.unwrap_or_else(|| estimator.name()),
                estimator,
                psi,
                tau,
                lambda,
                cv_error: crate::util::mean(&errs),
            }
        }));
    }
    let table = pool.run_all(jobs);

    // first strictly-better point wins ties (deterministic in grid order)
    let mut best = &table[0];
    for p in &table[1..] {
        if p.cv_error < best.cv_error {
            best = p;
        }
    }
    Ok(GridSearchResult {
        best: best.estimator,
        best_name: best.name.clone(),
        best_psi: best.psi,
        best_tau: best.tau,
        best_lambda: best.lambda,
        best_cv_error: best.cv_error,
        search_secs: timer.secs(),
        table,
    })
}

/// Grid search for the polynomial-kernel SVM baseline (degree × λ).
pub fn grid_search_kernel_svm(
    train: &Dataset,
    degrees: &[u32],
    lambdas: &[f64],
    folds: usize,
    seed: u64,
    pool: &ThreadPool,
) -> Result<(PolyKernelConfig, f64, f64)> {
    let timer = Timer::start();
    let fold_idx = kfold_indices(train.len(), folds, seed);
    let fold_data: Vec<(Dataset, Dataset)> = fold_idx
        .iter()
        .map(|(tr, va)| (train.subset(tr), train.subset(va)))
        .collect();

    let mut jobs: Vec<Box<dyn FnOnce() -> (u32, f64, f64) + Send>> = Vec::new();
    for &degree in degrees {
        for &lambda in lambdas {
            let fold_data = fold_data.clone();
            jobs.push(Box::new(move || {
                let mut errs = Vec::new();
                for (tr, va) in &fold_data {
                    let cfg = PolyKernelConfig { degree, lambda, ..Default::default() };
                    match PolyKernelSvm::fit(&tr.x, &tr.y, tr.n_classes, cfg) {
                        Ok(svm) => errs.push(error_rate(&svm.predict(&va.x), &va.y)),
                        Err(_) => errs.push(1.0),
                    }
                }
                (degree, lambda, crate::util::mean(&errs))
            }));
        }
    }
    let table = pool.run_all(jobs);
    let mut best = (degrees[0], lambdas[0], f64::INFINITY);
    for &(d, l, e) in &table {
        if e < best.2 {
            best = (d, l, e);
        }
    }
    Ok((
        PolyKernelConfig { degree: best.0, lambda: best.1, ..Default::default() },
        best.2,
        timer.secs(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::synthetic_dataset;
    use crate::oavi::OaviConfig;

    #[test]
    fn grid_search_selects_reasonable_psi() {
        let ds = synthetic_dataset(400, 3);
        let pool = ThreadPool::new(2);
        let res = grid_search(
            &[EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))],
            FeatureOrdering::Pearson,
            &ds,
            &[0.05, 0.005],
            &[1e-3],
            3,
            7,
            &pool,
        )
        .unwrap();
        assert_eq!(res.table.len(), 2);
        assert!(res.best_cv_error <= 0.5);
        assert!(res.table.iter().any(|p| p.psi == res.best_psi));
        assert_eq!(res.best.psi(), res.best_psi);
        assert_eq!(res.best_name, "CGAVI-IHB");
        assert!(res.search_secs > 0.0);
    }

    #[test]
    fn mixed_method_grid_search_races_estimators() {
        let ds = synthetic_dataset(300, 5);
        let pool = ThreadPool::new(2);
        let battery = EstimatorConfig::battery(0.01);
        let res = grid_search(
            &battery,
            FeatureOrdering::Pearson,
            &ds,
            &[0.01],
            &[1e-3],
            2,
            9,
            &pool,
        )
        .unwrap();
        assert_eq!(res.table.len(), battery.len());
        // the winner's name is one of the battery's fitted names
        let names: Vec<String> = battery.iter().map(|c| c.name()).collect();
        assert!(names.contains(&res.best_name), "winner {}", res.best_name);
        // every grid point reports through its FitReport name
        for p in &res.table {
            assert!(names.contains(&p.name));
            assert!(p.cv_error.is_finite());
        }
    }

    #[test]
    fn empty_psis_uses_estimator_hyper_grid_with_tau_axis() {
        use crate::estimator::TAU_GRID;
        let ds = synthetic_dataset(200, 6);
        let pool = ThreadPool::new(2);
        let res = grid_search(
            &[EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))],
            FeatureOrdering::Pearson,
            &ds,
            &[],
            &[1e-3],
            2,
            11,
            &pool,
        )
        .unwrap();
        // CGAVI-IHB is ℓ1-constrained, so per-method mode sweeps ψ × τ
        assert_eq!(res.table.len(), PSI_GRID.len() * TAU_GRID.len());
        assert!(res.table.iter().all(|p| p.tau.is_some()));
        assert_eq!(res.best_tau, res.table.iter().find(|p| p.cv_error == res.best_cv_error).unwrap().tau);
        // the winning config carries the swept τ
        assert_eq!(res.best.tau(), res.best_tau);
        assert!(
            grid_search(&[], FeatureOrdering::Pearson, &ds, &[], &[1e-3], 2, 11, &pool).is_err()
        );
    }

    #[test]
    fn explicit_psi_grid_pins_tau_at_the_config_default() {
        let ds = synthetic_dataset(200, 14);
        let pool = ThreadPool::new(2);
        let res = grid_search(
            &[EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))],
            FeatureOrdering::Pearson,
            &ds,
            &[0.05, 0.005],
            &[1e-3],
            2,
            11,
            &pool,
        )
        .unwrap();
        assert_eq!(res.table.len(), 2, "explicit ψ grid must not sweep τ");
        assert!(res.table.iter().all(|p| p.tau.is_none()));
        assert_eq!(res.best.tau(), Some(1000.0));
    }

    #[test]
    fn empty_lambdas_use_per_method_lambda_grid() {
        use crate::baselines::abm::AbmConfig;
        let ds = synthetic_dataset(200, 15);
        let pool = ThreadPool::new(2);
        // ABM: no τ axis, default λ grid
        let res = grid_search(
            &[EstimatorConfig::Abm(AbmConfig::new(0.01))],
            FeatureOrdering::Pearson,
            &ds,
            &[0.01],
            &[],
            2,
            11,
            &pool,
        )
        .unwrap();
        assert_eq!(res.table.len(), LAMBDA_GRID.len());
        assert!(res.table.iter().all(|p| p.tau.is_none()));
        // WIHB overrides the λ range
        let res = grid_search(
            &[EstimatorConfig::Oavi(OaviConfig::bpcgavi_wihb(0.01))],
            FeatureOrdering::Pearson,
            &ds,
            &[0.01],
            &[],
            2,
            11,
            &pool,
        )
        .unwrap();
        let lambdas: Vec<f64> = res.table.iter().map(|p| p.lambda).collect();
        assert_eq!(lambdas, crate::estimator::WIHB_LAMBDA_GRID.to_vec());
    }

    #[test]
    fn vca_per_method_psi_grid_applies() {
        use crate::baselines::vca::VcaConfig;
        let ds = synthetic_dataset(150, 16);
        let pool = ThreadPool::new(2);
        let res = grid_search(
            &[EstimatorConfig::Vca(VcaConfig::new(0.01))],
            FeatureOrdering::Pearson,
            &ds,
            &[],
            &[1e-3],
            2,
            11,
            &pool,
        )
        .unwrap();
        assert_eq!(res.table.len(), crate::estimator::VCA_PSI_GRID.len());
        assert!(res.table.iter().all(|p| p.tau.is_none()));
    }

    #[test]
    fn sharded_grid_search_runs_and_agrees_on_small_fits() {
        // small m ⇒ preferred_shards = 1 ⇒ identical arithmetic to the
        // single-threaded search
        let ds = synthetic_dataset(300, 8);
        let pool = ThreadPool::new(2);
        let est = [EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))];
        let base =
            grid_search(&est, FeatureOrdering::Pearson, &ds, &[0.05], &[1e-3], 3, 7, &pool)
                .unwrap();
        let sharded = grid_search_sharded(
            &est,
            FeatureOrdering::Pearson,
            &ds,
            &[0.05],
            &[1e-3],
            3,
            7,
            &pool,
            2,
        )
        .unwrap();
        assert_eq!(base.table.len(), sharded.table.len());
        assert_eq!(base.best_cv_error, sharded.best_cv_error);
    }

    #[test]
    fn two_level_auto_budget_matches_explicit_grid() {
        let ds = synthetic_dataset(300, 12);
        let pool = ThreadPool::new(4);
        let est = [EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))];
        let base =
            grid_search(&est, FeatureOrdering::Pearson, &ds, &[0.05, 0.01], &[1e-3], 2, 3, &pool)
                .unwrap();
        let auto = grid_search_two_level(
            &est,
            FeatureOrdering::Pearson,
            &ds,
            &[0.05, 0.01],
            &[1e-3],
            2,
            3,
            &pool,
            GridParallelism::auto(),
        )
        .unwrap();
        // small folds ⇒ preferred_shards = 1 ⇒ same arithmetic even when
        // the auto split hands each job an inner budget > 1
        assert_eq!(base.table.len(), auto.table.len());
        for (a, b) in base.table.iter().zip(auto.table.iter()) {
            assert_eq!(a.cv_error, b.cv_error);
            assert_eq!(a.name, b.name);
        }
        assert_eq!(base.best_cv_error, auto.best_cv_error);
    }

    #[test]
    fn pinned_store_shards_is_deterministic_across_worker_budgets() {
        let ds = synthetic_dataset(240, 13);
        let pool = ThreadPool::new(3);
        let est = [EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))];
        let run = |intra: usize| {
            grid_search_two_level(
                &est,
                FeatureOrdering::Pearson,
                &ds,
                &[0.05],
                &[1e-3],
                2,
                5,
                &pool,
                GridParallelism { intra_workers: intra, pin_store_shards: Some(3) },
            )
            .unwrap()
        };
        let a = run(1);
        let b = run(2);
        assert_eq!(a.table.len(), b.table.len());
        for (pa, pb) in a.table.iter().zip(b.table.iter()) {
            assert_eq!(pa.cv_error.to_bits(), pb.cv_error.to_bits());
        }
    }

    #[test]
    fn kernel_grid_runs() {
        let ds = synthetic_dataset(200, 4);
        let pool = ThreadPool::new(2);
        let (cfg, err, secs) =
            grid_search_kernel_svm(&ds, &[2, 3], &[1e-3], 3, 5, &pool).unwrap();
        assert!(cfg.degree == 2 || cfg.degree == 3);
        assert!(err <= 0.6);
        assert!(secs > 0.0);
    }
}

//! Serve-transform bench: cold-rebuild vs compiled-plan per-request cost.
//!
//! The legacy request path re-derives everything x-independent on every
//! call (permutation buffer, per-class eval stores, `C`/`U` operands,
//! per-class block matrices + concatenation); the compiled
//! [`TransformPlan`] hoists all of it to build time and serves from
//! per-worker scratch.  This bench measures both paths per request at
//! m ∈ {1, 32, 1024} rows, dense and forced-sparse kernels, plus the
//! steady-state scratch growth count (must be 0), and emits
//! `BENCH_serve_transform.json` for the trajectory gate
//! (AVI_BENCH_REPS to grow).

use std::sync::Arc;
use std::time::Instant;

use avi_scale::backend::NativeBackend;
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::estimator::plan::PlanPolicy;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::linalg::dense::Matrix;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::plan::{TransformPlan, TransformScratch};
use avi_scale::pipeline::{train_pipeline, PipelineConfig};
use avi_scale::svm::linear::LinearSvmConfig;

fn main() {
    let base_reps: usize = std::env::var("AVI_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let ds = synthetic_dataset(2_000, 9);
    let cfg = PipelineConfig {
        estimator: EstimatorConfig::parse("cgavi-ihb", 0.01).unwrap(),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    let model = Arc::new(train_pipeline(&cfg, &ds).unwrap());

    let t0 = Instant::now();
    let dense = TransformPlan::build(Arc::clone(&model), &PlanPolicy::default());
    let dense_build = t0.elapsed();
    let t0 = Instant::now();
    let sparse = TransformPlan::build(
        Arc::clone(&model),
        &PlanPolicy { sparse: true, sparse_min_zero_frac: 0.0 },
    );
    let sparse_build = t0.elapsed();

    let mut json = avi_scale::bench::BenchJson::new("serve_transform");
    json.int("n_generators", model.transformer.n_generators() as u64);
    json.ns("plan_build_dense", dense_build.as_secs_f64());
    json.ns("plan_build_sparse", sparse_build.as_secs_f64());
    json.int("sparse_classes", sparse.sparse_classes() as u64);
    json.int("sparse_flops_saved_per_row", sparse.flops_saved_per_row());

    println!(
        "model: |G| = {}, plan build dense = {:?}, sparse = {:?} ({} sparse classes)",
        model.transformer.n_generators(),
        dense_build,
        sparse_build,
        sparse.sparse_classes()
    );
    println!(
        "{:>6} {:>16} {:>16} {:>16} {:>9}",
        "m", "cold ns/req", "prepared ns/req", "sparse ns/req", "speedup"
    );

    for &m in &[1usize, 32, 1024] {
        // keep total rows touched roughly constant across cells
        let reps = (base_reps * 64 / m.max(1)).clamp(20, 20_000);
        let rows: Vec<Vec<f64>> = (0..m).map(|i| ds.x.row(i % ds.len()).to_vec()).collect();
        let probe = Matrix::from_rows(&rows).unwrap();

        // cold rebuild: the pre-plan request path
        let t0 = Instant::now();
        for _ in 0..reps {
            let (labels, _) = model.predict_scores_with_backend(&probe, &NativeBackend);
            assert_eq!(labels.len(), m);
        }
        let cold_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;

        // prepared dense: warm once, then steady state must not grow
        let mut scratch = TransformScratch::new();
        let _ = dense.predict_scores(&probe, &mut scratch);
        let grows_before = scratch.grows();
        let t0 = Instant::now();
        for _ in 0..reps {
            let (labels, _) = dense.predict_scores(&probe, &mut scratch);
            assert_eq!(labels.len(), m);
        }
        let prep_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
        let steady_grows = scratch.grows() - grows_before;
        assert_eq!(steady_grows, 0, "m={m}: steady-state scratch growth");

        // prepared sparse (forced): the packed-column kernel
        let mut sp_scratch = TransformScratch::new();
        let _ = sparse.predict_scores(&probe, &mut sp_scratch);
        let t0 = Instant::now();
        for _ in 0..reps {
            let (labels, _) = sparse.predict_scores(&probe, &mut sp_scratch);
            assert_eq!(labels.len(), m);
        }
        let sparse_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;

        println!(
            "{m:>6} {cold_ns:>16.0} {prep_ns:>16.0} {sparse_ns:>16.0} {:>8.2}x",
            cold_ns / prep_ns
        );
        json.ns(&format!("cold_m{m}"), cold_ns / 1e9);
        json.ns(&format!("prepared_m{m}"), prep_ns / 1e9);
        json.ns(&format!("prepared_sparse_m{m}"), sparse_ns / 1e9);
        json.num(&format!("speedup_m{m}"), cold_ns / prep_ns);
        json.int(&format!("steady_state_grows_m{m}"), steady_grows);
    }

    json.write().expect("write BENCH_serve_transform.json");
}

//! Figure 1: (left) the Theorem 4.3 bound on |G|+|O| vs ψ for several n;
//! (right) theoretical bound vs empirical |G|+|O| for CGAVI on random
//! data (ψ = 0.005), with the n⁴ guide line.

use avi_scale::bench::figures::{fig1_bound_curves, fig1_empirical};
use avi_scale::bench::report_figure;

fn main() {
    let psis: Vec<f64> = (0..12).map(|i| 10f64.powf(-0.5 - 0.35 * i as f64)).collect();
    let left = fig1_bound_curves(&[1, 10, 50, 100, 250], &psis);
    report_figure("fig1_left_bound_vs_psi", "psi*1e6", &{
        // x column in csv-friendly form
        let mut scaled = left.clone();
        for s in &mut scaled {
            for p in &mut s.points {
                p.0 *= 1e6;
            }
        }
        scaled
    });

    let m: usize = std::env::var("AVI_BENCH_M")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000); // paper: 10,000
    let runs: usize = std::env::var("AVI_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3); // paper: 10
    let right = fig1_empirical(m, &[1, 2, 3, 4, 5], 0.005, runs, 0xF1).expect("fig1 right");
    report_figure("fig1_right_bound_vs_empirical", "n", &right);
    println!("\nshape check: empirical |G|+|O| ≤ bound for every n (paper: slightly smaller)");
}

//! Micro: linalg substrate timings — Theorem 4.9 append (O(ℓ²)) vs
//! Cholesky rebuild (O(ℓ³)), Jacobi eigen, and the gram_stats hot loop.

use avi_scale::backend::{ColumnStore, ComputeBackend, NativeBackend};
use avi_scale::bench::{report_figure, Bencher, Series};
use avi_scale::linalg::eigen::sym_eig;
use avi_scale::linalg::gram::GramState;
use avi_scale::util::rng::Rng;

fn main() {
    let bencher = Bencher::new(1, 7);
    let mut rng = Rng::new(7);
    let mut append_series = Series::new("thm4.9_append");
    let mut rebuild_series = Series::new("cholesky_rebuild");
    let mut eig_series = Series::new("jacobi_eig");
    for &ell in &[16usize, 32, 64, 128] {
        let m = 2000;
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let newcol: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let gram = GramState::from_columns(&cols).unwrap();
        let atb: Vec<f64> =
            cols.iter().map(|c| avi_scale::linalg::dot(c, &newcol)).collect();
        let btb = avi_scale::linalg::dot(&newcol, &newcol);

        let stat = bencher.run("append", || {
            let mut g = gram.clone();
            g.append(&atb, btb).unwrap();
            g
        });
        append_series.push_obs(ell as f64, &[stat.median_s]);

        let stat = bencher.run("rebuild", || {
            let mut g = gram.clone();
            g.rebuild_inverse().unwrap();
            g
        });
        rebuild_series.push_obs(ell as f64, &[stat.median_s]);

        let b = gram.b().clone();
        let stat = bencher.run("eig", || sym_eig(&b, 30).unwrap());
        eig_series.push_obs(ell as f64, &[stat.median_s]);

        let store = ColumnStore::from_cols(&cols, 1);
        let stat = bencher.run("gram_stats", || NativeBackend.gram_stats(&store, &newcol));
        println!(
            "ell={ell:>4}: gram_stats {:.1}us ({:.2} GB/s effective)",
            stat.median_s * 1e6,
            (m * ell * 8) as f64 / stat.median_s / 1e9
        );
    }
    report_figure(
        "micro_linalg",
        "ell",
        &[append_series, rebuild_series, eig_series],
    );
    println!("shape check: append grows ~ell^2, rebuild ~ell^3 (appendix A claim)");
}

//! Table 1: CGAVI-IHB+SVM test error with Pearson vs reverse-Pearson
//! feature ordering — the §5 ablation showing the choice barely matters.

use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::data::load_registry_dataset;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::oavi::OaviConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::report::{run_cell, Method, Protocol};

fn main() {
    let scale: f64 = std::env::var("AVI_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02);
    let splits: usize = std::env::var("AVI_BENCH_SPLITS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3); // paper: 10
    let pool = ThreadPool::default_size();
    println!("{:<10} {:>14} {:>18}", "dataset", "Pearson err%", "rev-Pearson err%");
    let mut rows = Vec::new();
    for name in ["bank", "credit", "htru", "seeds", "skin", "spam"] {
        let ds = load_registry_dataset(name, scale, 3).expect("dataset");
        let mut errs = Vec::new();
        for ordering in [FeatureOrdering::Pearson, FeatureOrdering::ReversePearson] {
            let protocol = Protocol {
                n_splits: splits,
                cv_folds: 3,
                psis: &[0.01, 0.005],
                lambdas: &[1e-3],
                ordering,
                ..Default::default()
            };
            let cell = run_cell(
                Method::Estimator(EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.005))),
                &ds,
                &protocol,
                &pool,
            )
            .expect("cell");
            errs.push(cell.error_mean * 100.0);
        }
        println!("{name:<10} {:>14.2} {:>18.2}", errs[0], errs[1]);
        rows.push(vec![errs[0], errs[1]]);
    }
    let _ = avi_scale::data::csvio::write_csv(
        std::path::Path::new("target/bench_results/table1_ordering.csv"),
        &["pearson_err_pct", "reverse_err_pct"],
        &rows,
    );
    println!("\nshape check: the two columns should be close (paper: ±0.15pp)");
}

//! Figure 3: training time of BPCGAVI vs BPCGAVI-WIHB vs CGAVI-IHB over
//! the number of training samples (ψ = 0.005).
//!
//! Paper shape: CGAVI-IHB < BPCGAVI-WIHB < BPCGAVI, and (synthetic) the
//! training time is linear in m.

use avi_scale::bench::figures::{fig3_methods, training_time_sweep, SweepSpec};
use avi_scale::bench::report_figure;

fn main() {
    let mut spec = SweepSpec::quick();
    if let Ok(s) = std::env::var("AVI_BENCH_SCALE") {
        spec.scale = s.parse().unwrap_or(spec.scale);
    }
    if let Ok(r) = std::env::var("AVI_BENCH_RUNS") {
        spec.runs = r.parse().unwrap_or(spec.runs);
    }
    let blocks = training_time_sweep(&fig3_methods(), &spec).expect("sweep");
    for (ds, series) in &blocks {
        report_figure(&format!("fig3_{ds}"), "m", series);
    }
    println!("\nshape check (largest m): expect CGAVI-IHB ≤ BPCGAVI-WIHB ≤ BPCGAVI");
    for (ds, series) in &blocks {
        let vals: Vec<(String, f64)> = series
            .iter()
            .map(|s| (s.name.clone(), s.points.last().unwrap().1))
            .collect();
        println!("  {ds:<10} {:?}", vals);
    }
    // linearity check on synthetic: time(m)/m roughly constant
    if let Some((_, series)) = blocks.iter().find(|(d, _)| d == "synthetic") {
        let ihb = series.iter().find(|s| s.name == "CGAVI-IHB").unwrap();
        if ihb.points.len() >= 2 {
            let per_m: Vec<f64> = ihb.points.iter().map(|&(m, t, _)| t / m).collect();
            println!("  synthetic CGAVI-IHB time/m: {per_m:?} (≈constant ⇒ linear in m)");
        }
    }
}

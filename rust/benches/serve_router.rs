//! Serving control-plane bench: throughput/latency of the router under a
//! weighted A/B split with a shadow route, reported as the same
//! `RouterReport` JSON the CLI emits (AVI_BENCH_REQUESTS to grow).

use std::sync::Arc;
use std::time::Instant;

use avi_scale::coordinator::registry::ModelRegistry;
use avi_scale::coordinator::router::ModelRouter;
use avi_scale::coordinator::service::{latency_percentiles, ServeConfig, ServeRequest};
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::{train_pipeline, PipelineConfig};
use avi_scale::svm::linear::LinearSvmConfig;

fn main() {
    let n_req: usize = std::env::var("AVI_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5_000);
    let ds = synthetic_dataset(4_000, 9);
    let train = |method: &str, psi: f64| {
        let cfg = PipelineConfig {
            estimator: EstimatorConfig::parse(method, psi).unwrap(),
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        Arc::new(train_pipeline(&cfg, &ds).unwrap())
    };
    let mut registry = ModelRegistry::new();
    registry.insert("m", "v1", train("cgavi-ihb", 0.01)).unwrap();
    registry.insert("m", "v2", train("bpcgavi-wihb", 0.01)).unwrap();
    registry.insert("m", "cand", train("abm", 0.01)).unwrap();

    // the bench enqueues the whole request set before waiting, so size
    // the admission queue to hold it (the default 1024 bound would
    // correctly reject the overflow — measured separately)
    let cfg = ServeConfig::new().queue_capacity(n_req);
    let router = ModelRouter::new();
    router
        .register_ab(
            &registry,
            "m",
            &[("v1".into(), 70), ("v2".into(), 30)],
            42,
            &cfg,
        )
        .unwrap();
    router
        .set_shadow("m", "cand", registry.resolve("m", "cand").unwrap(), cfg.clone())
        .unwrap();

    let rows: Vec<Vec<f64>> = (0..n_req).map(|i| ds.x.row(i % ds.len()).to_vec()).collect();
    let t0 = Instant::now();
    let pendings: Vec<_> = rows
        .into_iter()
        .map(|row| router.enqueue("m", ServeRequest::row(row)).unwrap())
        .collect();
    let mut lat_us = Vec::with_capacity(n_req);
    for p in pendings {
        let ans = p.wait().answer().expect("answered");
        lat_us.push((ans.queue_latency + ans.compute_latency).as_secs_f64() * 1e6);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (p50, p95, p99) = latency_percentiles(lat_us);
    println!("requests    = {n_req}");
    println!("throughput  = {:.0} req/s", n_req as f64 / wall);
    println!("latency p50 = {p50:.0}us  p95 = {p95:.0}us  p99 = {p99:.0}us");
    let report = router.report();
    assert_eq!(report.total_requests, n_req as u64, "router lost traffic");
    println!("{}", report.to_json());

    // machine-readable digest for the trajectory gate (ROADMAP 3a): the
    // wall + latency cells ride the `_ns` convention the gate compares;
    // the RouterReport counters ride as plain integer cells.
    let mut json = avi_scale::bench::BenchJson::new("serve_router");
    json.int("requests", n_req as u64);
    json.num("throughput_req_s", n_req as f64 / wall);
    json.ns("wall", wall);
    json.ns("latency_p50", p50 / 1e6);
    json.ns("latency_p95", p95 / 1e6);
    json.ns("latency_p99", p99 / 1e6);
    json.int("total_requests", report.total_requests);
    json.int("total_rejected", report.total_rejected);
    for r in &report.routes {
        let tag = format!("route_{}_{}", r.role, r.version);
        json.int(&format!("{tag}_requests"), r.requests);
        json.int(&format!("{tag}_mirrored"), r.mirrored);
        json.int(&format!("{tag}_batches"), r.batches);
        json.int(&format!("{tag}_max_batch"), r.max_batch);
        json.num(&format!("{tag}_mean_queue_us"), r.mean_queue_us);
        json.num(&format!("{tag}_mean_compute_us"), r.mean_compute_us);
    }
    json.write().expect("write BENCH_serve_router.json");
}

//! Micro: solver-family iteration counts and wall time on fixed Gram
//! instances — the §4.3 story (BPCG vs PCG vs CG) at the oracle level.

use avi_scale::bench::{Bencher, Series, report_figure};
use avi_scale::linalg::gram::GramState;
use avi_scale::solvers::{GramProblem, SolverKind, SolverParams};
use avi_scale::util::rng::Rng;

fn instance(rng: &mut Rng, m: usize, ell: usize) -> (GramState, Vec<f64>, f64) {
    let cols: Vec<Vec<f64>> =
        (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
    let b: Vec<f64> = (0..m).map(|_| rng.uniform() - 0.4).collect();
    let gram = GramState::from_columns(&cols).unwrap();
    let atb: Vec<f64> = cols.iter().map(|c| avi_scale::linalg::dot(c, &b)).collect();
    let btb = avi_scale::linalg::dot(&b, &b);
    (gram, atb, btb)
}

fn main() {
    let bencher = Bencher::new(1, 7);
    let mut rng = Rng::new(0xBEEF);
    let mut time_series: Vec<Series> = Vec::new();
    let solvers = [SolverKind::Cg, SolverKind::Pcg, SolverKind::Bpcg, SolverKind::Agd];
    let mut per_solver: Vec<Series> =
        solvers.iter().map(|s| Series::new(s.name())).collect();
    for &ell in &[8usize, 16, 32, 64] {
        let (gram, atb, btb) = instance(&mut rng, 500, ell);
        let p = GramProblem { b: gram.b(), atb: &atb, btb, m: 500 };
        // tight ball so FW variants actually iterate
        let params = SolverParams { eps: 1e-8, max_iters: 20_000, radius: 0.5, psi: None };
        for (si, solver) in solvers.iter().enumerate() {
            let params = if *solver == SolverKind::Agd {
                SolverParams { radius: 0.0, ..params }
            } else {
                params
            };
            let stat = bencher.run(&format!("{}_{ell}", solver.name()), || {
                solver.solve(&p, &params)
            });
            let res = solver.solve(&p, &params);
            println!(
                "ell={ell:>3} {:<5} median {:>10.3}us  iters {:>6}  f {:.3e}  ({:?})",
                solver.name(),
                stat.median_s * 1e6,
                res.iters,
                res.f,
                res.termination
            );
            per_solver[si].push_obs(ell as f64, &[stat.median_s]);
        }
    }
    time_series.append(&mut per_solver);
    report_figure("micro_solvers", "ell", &time_series);
    println!("shape check: BPCG should need no more iterations than PCG on boundary problems");
}

//! Figure 4: training times of CGAVI-IHB, BPCGAVI-WIHB, AGDAVI-IHB, ABM,
//! and VCA over the number of training samples.
//!
//! Paper shape: ABM/VCA can win at small m but scale worse; the OAVI-IHB
//! family is fastest at large m (linear in m).

use avi_scale::bench::figures::{fig4_methods, training_time_sweep, SweepSpec};
use avi_scale::bench::report_figure;

fn main() {
    let mut spec = SweepSpec::quick();
    if let Ok(s) = std::env::var("AVI_BENCH_SCALE") {
        spec.scale = s.parse().unwrap_or(spec.scale);
    }
    if let Ok(r) = std::env::var("AVI_BENCH_RUNS") {
        spec.runs = r.parse().unwrap_or(spec.runs);
    }
    let blocks = training_time_sweep(&fig4_methods(), &spec).expect("sweep");
    for (ds, series) in &blocks {
        report_figure(&format!("fig4_{ds}"), "m", series);
    }
    println!("\nshape check: growth factor time(max m)/time(min m) per method");
    for (ds, series) in &blocks {
        print!("  {ds:<10}");
        for s in series {
            let first = s.points.first().unwrap().1.max(1e-9);
            let last = s.points.last().unwrap().1;
            print!(" {}={:.1}x", s.name, last / first);
        }
        println!();
    }
}

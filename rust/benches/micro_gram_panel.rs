//! Micro: degree-batched candidate panels vs the per-candidate
//! `gram_stats` loop (ISSUE 5 acceptance gates).
//!
//! Two layers of measurement:
//!
//! * **kernel** — per-call timing of k per-candidate `gram_stats` passes
//!   vs one `gram_panel` pass over the same store/panel, m ∈
//!   {1e3, 1e4, 1e5}, native and pool-sharded, with the pool's batch
//!   counter reporting dispatches per degree (per-candidate = k, panel
//!   = 1).  Results are asserted bitwise identical before timing, so a
//!   perf reading can never come from divergent arithmetic.  The
//!   `panel(no-cross)` column is FLOP-identical to the per-candidate
//!   loop; `panel(+cross)` additionally buys the k×k cross-Gram cache
//!   that the driver's within-degree walk consumes.
//! * **end-to-end** — a full sharded OAVI fit through the panel path vs
//!   the legacy per-candidate path, with the dispatch totals that
//!   attribute the win.
//!
//! Acceptance bar: the panel kernel beats the per-candidate loop on the
//! sharded backend at m ≥ 1e4 (dispatch amortization + shared b-passes).

use avi_scale::backend::{CandidatePanel, ColumnStore, ComputeBackend, NativeBackend, ShardedBackend};
use avi_scale::bench::Bencher;
use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::oavi::{Oavi, OaviConfig};
use avi_scale::util::rng::Rng;
use avi_scale::util::timer::Timer;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn kernel_bench(bencher: &Bencher, pool: &ThreadPool) {
    println!("-- kernel: k per-candidate gram_stats vs one gram_panel --");
    println!(
        "{:>8} {:>6} {:>4} | {:>12} {:>14} {:>14} {:>8} | {:>12} {:>14} {:>8} | {:>10}",
        "m",
        "ell",
        "k",
        "percand_ns",
        "panel_ns",
        "panel+x_ns",
        "speedup",
        "sh_percand",
        "sh_panel",
        "speedup",
        "disp/deg"
    );
    for &m in &[1_000usize, 10_000, 100_000] {
        let (ell, k) = (24usize, 32usize);
        let mut rng = Rng::new(7 + m as u64);
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let store = ColumnStore::from_cols(&cols, 4);
        let mut panel = CandidatePanel::new_like(&store);
        let cands: Vec<Vec<f64>> =
            (0..k).map(|_| (0..m).map(|_| rng.uniform() - 0.5).collect()).collect();
        for c in &cands {
            panel.push_col(c);
        }
        let native = NativeBackend;
        let sharded = ShardedBackend::with_handle(pool.handle(), 4, 64).with_min_work(0);

        // bitwise gate: panel path must reproduce the per-candidate bits
        let ps = native.gram_panel(&store, &panel, true);
        for (c, cand) in cands.iter().enumerate() {
            let (atb, btb) = native.gram_stats(&store, cand);
            assert_eq!(bits(&atb), bits(ps.atb_col(c)), "atb bits diverge at m={m} c={c}");
            assert_eq!(btb.to_bits(), ps.btb(c).to_bits(), "btb bits diverge at m={m} c={c}");
        }
        let pss = sharded.gram_panel(&store, &panel, true);
        for c in 0..k {
            assert_eq!(bits(ps.atb_col(c)), bits(pss.atb_col(c)));
            for i in 0..=c {
                assert_eq!(ps.cross_at(i, c).to_bits(), pss.cross_at(i, c).to_bits());
            }
        }

        let id = |tag: &str| format!("{tag}_m{m}");
        let t_pc_n = bencher.run(&id("gram_percand_native"), || {
            for cand in &cands {
                std::hint::black_box(native.gram_stats(&store, cand));
            }
        });
        let t_pn_n = bencher
            .run(&id("gram_panel_native"), || std::hint::black_box(native.gram_panel(&store, &panel, false)));
        let t_px_n = bencher
            .run(&id("gram_panelx_native"), || std::hint::black_box(native.gram_panel(&store, &panel, true)));
        let d0 = pool.handle().batches_dispatched();
        let t_pc_s = bencher.run(&id("gram_percand_sharded"), || {
            for cand in &cands {
                std::hint::black_box(sharded.gram_stats(&store, cand));
            }
        });
        let d1 = pool.handle().batches_dispatched();
        let t_pn_s = bencher
            .run(&id("gram_panel_sharded"), || std::hint::black_box(sharded.gram_panel(&store, &panel, false)));
        let d2 = pool.handle().batches_dispatched();
        let runs = (bencher.warmup + bencher.iters) as u64;
        println!(
            "{:>8} {:>6} {:>4} | {:>12.0} {:>14.0} {:>14.0} {:>7.2}x | {:>12.0} {:>14.0} {:>7.2}x | {:>4} vs {:>2}",
            m,
            ell,
            k,
            t_pc_n.median_s * 1e9,
            t_pn_n.median_s * 1e9,
            t_px_n.median_s * 1e9,
            t_pc_n.median_s / t_pn_n.median_s,
            t_pc_s.median_s * 1e9,
            t_pn_s.median_s * 1e9,
            t_pc_s.median_s / t_pn_s.median_s,
            (d1 - d0) / runs,
            (d2 - d1) / runs,
        );
        if m >= 10_000 {
            let speedup = t_pc_s.median_s / t_pn_s.median_s;
            if speedup < 1.0 {
                println!(
                    "WARN: sharded panel kernel slower than per-candidate at m={m} \
                     ({speedup:.2}x) — acceptance bar is ≥ 1x at m ≥ 1e4"
                );
            }
        }
    }
}

fn fit_bench(pool: &ThreadPool) {
    println!("-- end-to-end: sharded OAVI fit, panel vs per-candidate --");
    let ds = synthetic_dataset(20_000, 11);
    let x = ds.class_matrix(0);
    let cfg = OaviConfig::cgavi_ihb(0.005);
    let backend = ShardedBackend::with_handle(pool.handle(), 4, 64);
    let d0 = pool.handle().batches_dispatched();
    let t = Timer::start();
    let legacy = Oavi::new(cfg).fit_with_backend_per_candidate(&x, &backend).unwrap();
    let legacy_s = t.secs();
    let d1 = pool.handle().batches_dispatched();
    let t = Timer::start();
    let panel = Oavi::new(cfg).fit_with_backend(&x, &backend).unwrap();
    let panel_s = t.secs();
    let d2 = pool.handle().batches_dispatched();
    // same model, attributable speedup
    assert_eq!(legacy.generators.len(), panel.generators.len());
    assert_eq!(legacy.o_terms.len(), panel.o_terms.len());
    println!(
        "per-candidate: {:.3}s ({} dispatches)   panel: {:.3}s ({} dispatches, {} passes, \
         {} cross-cache hits)   speedup {:.2}x",
        legacy_s,
        d1 - d0,
        panel_s,
        d2 - d1,
        panel.stats.panel_passes,
        panel.stats.cross_cache_hits,
        legacy_s / panel_s
    );
}

fn main() {
    let bencher = Bencher::new(1, 5);
    let pool = ThreadPool::new(4);
    println!("== micro_gram_panel: degree-batched panels vs per-candidate loop ==");
    kernel_bench(&bencher, &pool);
    fit_bench(&pool);
}

//! Micro: degree-batched candidate panels vs the per-candidate
//! `gram_stats` loop (ISSUE 5 acceptance gates), plus the ISSUE 6
//! row-tiled/wide-lane kernel A/B and the exact-vs-fast error budget.
//!
//! Measurement layers:
//!
//! * **kernel** — per-call timing of k per-candidate `gram_stats` passes
//!   vs one `gram_panel` pass over the same store/panel, m ∈
//!   {1e3, 1e4, 1e5}, native and pool-sharded, with the pool's batch
//!   counter reporting dispatches per degree (per-candidate = k, panel
//!   = 1).  Results are asserted bitwise identical before timing, so a
//!   perf reading can never come from divergent arithmetic.  The
//!   `panel(no-cross)` column is FLOP-identical to the per-candidate
//!   loop; `panel(+cross)` additionally buys the k×k cross-Gram cache
//!   that the driver's within-degree walk consumes.
//! * **tiled A/B** — the scalar per-candidate panel kernel vs the
//!   row-tiled wide-lane micro-kernel on the SAME store/panel, pinned
//!   through the `set_block_threshold_bytes` override hook (usize::MAX
//!   forces the scalar path, 1 forces the tiled path), bitwise-gated
//!   before timing.  Acceptance bar: tiled ≥ scalar at m ∈ {1e4, 1e5}.
//! * **fast budget** — max |Δ| of the opt-in f32 fast panel vs the f64
//!   reference, reported next to the 1e-3 budget the driver asserts.
//! * **end-to-end** — a full sharded OAVI fit through the panel path vs
//!   the legacy per-candidate path, with the dispatch totals that
//!   attribute the win.
//!
//! Every cell lands in `target/bench_results/BENCH_micro_gram_panel.json`
//! for `scripts/bench_gate.sh` to diff across commits.

use avi_scale::backend::store::{set_block_threshold_bytes, BLOCK_THRESHOLD_DEFAULT};
use avi_scale::backend::{
    CandidatePanel, ColumnStore, ComputeBackend, CrossMode, NativeBackend, NumericsMode,
    ShardedBackend,
};
use avi_scale::bench::{BenchJson, Bencher};
use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::oavi::{Oavi, OaviConfig};
use avi_scale::util::rng::Rng;
use avi_scale::util::timer::Timer;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn kernel_bench(bencher: &Bencher, pool: &ThreadPool, json: &mut BenchJson) {
    println!("-- kernel: k per-candidate gram_stats vs one gram_panel --");
    println!(
        "{:>8} {:>6} {:>4} | {:>12} {:>14} {:>14} {:>8} | {:>12} {:>14} {:>8} | {:>10}",
        "m",
        "ell",
        "k",
        "percand_ns",
        "panel_ns",
        "panel+x_ns",
        "speedup",
        "sh_percand",
        "sh_panel",
        "speedup",
        "disp/deg"
    );
    for &m in &[1_000usize, 10_000, 100_000] {
        let (ell, k) = (24usize, 32usize);
        let mut rng = Rng::new(7 + m as u64);
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let store = ColumnStore::from_cols(&cols, 4);
        let mut panel = CandidatePanel::new_like(&store);
        let cands: Vec<Vec<f64>> =
            (0..k).map(|_| (0..m).map(|_| rng.uniform() - 0.5).collect()).collect();
        for c in &cands {
            panel.push_col(c);
        }
        let native = NativeBackend;
        let sharded = ShardedBackend::with_handle(pool.handle(), 4, 64).with_min_work(0);

        // bitwise gate: panel path must reproduce the per-candidate bits
        let ps = native.gram_panel(&store, &panel, CrossMode::Eager, NumericsMode::Exact);
        for (c, cand) in cands.iter().enumerate() {
            let (atb, btb) = native.gram_stats(&store, cand);
            assert_eq!(bits(&atb), bits(ps.atb_col(c)), "atb bits diverge at m={m} c={c}");
            assert_eq!(btb.to_bits(), ps.btb(c).to_bits(), "btb bits diverge at m={m} c={c}");
        }
        let pss = sharded.gram_panel(&store, &panel, CrossMode::Eager, NumericsMode::Exact);
        for c in 0..k {
            assert_eq!(bits(ps.atb_col(c)), bits(pss.atb_col(c)));
            for i in 0..=c {
                assert_eq!(ps.cross_at(i, c).to_bits(), pss.cross_at(i, c).to_bits());
            }
        }

        let id = |tag: &str| format!("{tag}_m{m}");
        let t_pc_n = bencher.run(&id("gram_percand_native"), || {
            for cand in &cands {
                std::hint::black_box(native.gram_stats(&store, cand));
            }
        });
        let t_pn_n = bencher.run(&id("gram_panel_native"), || {
            std::hint::black_box(native.gram_panel(
                &store,
                &panel,
                CrossMode::Skip,
                NumericsMode::Exact,
            ))
        });
        let t_px_n = bencher.run(&id("gram_panelx_native"), || {
            std::hint::black_box(native.gram_panel(
                &store,
                &panel,
                CrossMode::Eager,
                NumericsMode::Exact,
            ))
        });
        let d0 = pool.handle().batches_dispatched();
        let t_pc_s = bencher.run(&id("gram_percand_sharded"), || {
            for cand in &cands {
                std::hint::black_box(sharded.gram_stats(&store, cand));
            }
        });
        let d1 = pool.handle().batches_dispatched();
        let t_pn_s = bencher.run(&id("gram_panel_sharded"), || {
            std::hint::black_box(sharded.gram_panel(
                &store,
                &panel,
                CrossMode::Skip,
                NumericsMode::Exact,
            ))
        });
        let d2 = pool.handle().batches_dispatched();
        let runs = (bencher.warmup + bencher.iters) as u64;
        json.ns(&id("percand_native"), t_pc_n.median_s);
        json.ns(&id("panel_native"), t_pn_n.median_s);
        json.ns(&id("panelx_native"), t_px_n.median_s);
        json.ns(&id("percand_sharded"), t_pc_s.median_s);
        json.ns(&id("panel_sharded"), t_pn_s.median_s);
        json.int(&format!("dispatches_percand_m{m}"), (d1 - d0) / runs);
        json.int(&format!("dispatches_panel_m{m}"), (d2 - d1) / runs);
        println!(
            "{:>8} {:>6} {:>4} | {:>12.0} {:>14.0} {:>14.0} {:>7.2}x | {:>12.0} {:>14.0} {:>7.2}x | {:>4} vs {:>2}",
            m,
            ell,
            k,
            t_pc_n.median_s * 1e9,
            t_pn_n.median_s * 1e9,
            t_px_n.median_s * 1e9,
            t_pc_n.median_s / t_pn_n.median_s,
            t_pc_s.median_s * 1e9,
            t_pn_s.median_s * 1e9,
            t_pc_s.median_s / t_pn_s.median_s,
            (d1 - d0) / runs,
            (d2 - d1) / runs,
        );
        if m >= 10_000 {
            let speedup = t_pc_s.median_s / t_pn_s.median_s;
            if speedup < 1.0 {
                println!(
                    "WARN: sharded panel kernel slower than per-candidate at m={m} \
                     ({speedup:.2}x) — acceptance bar is ≥ 1x at m ≥ 1e4"
                );
            }
        }
    }
}

/// Scalar vs row-tiled/wide-lane panel kernel on identical inputs,
/// pinned through the block-threshold override hook (ISSUE 6 acceptance
/// A/B).  Both paths are bitwise-gated against each other before any
/// timing, so the speedup can never come from divergent arithmetic.
fn tiled_ab_bench(bencher: &Bencher, json: &mut BenchJson) {
    println!("-- tiled A/B: scalar panel kernel vs row-tiled wide-lane micro-kernel --");
    println!(
        "{:>8} {:>6} {:>4} | {:>12} {:>12} {:>8}",
        "m", "ell", "k", "scalar_ns", "tiled_ns", "speedup"
    );
    for &m in &[10_000usize, 100_000] {
        let (ell, k) = (24usize, 32usize);
        let mut rng = Rng::new(31 + m as u64);
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        // single shard: the whole m-row pass goes through one kernel call,
        // the regime where the row tiling works hardest
        let store = ColumnStore::from_cols(&cols, 1);
        let mut panel = CandidatePanel::new_like(&store);
        for _ in 0..k {
            let c: Vec<f64> = (0..m).map(|_| rng.uniform() - 0.5).collect();
            panel.push_col(&c);
        }
        let native = NativeBackend;

        // bitwise gate between the two pinned paths
        set_block_threshold_bytes(usize::MAX); // scalar per-candidate kernel
        let ps_scalar = native.gram_panel(&store, &panel, CrossMode::Skip, NumericsMode::Exact);
        set_block_threshold_bytes(1); // row-tiled wide-lane kernel
        let ps_tiled = native.gram_panel(&store, &panel, CrossMode::Skip, NumericsMode::Exact);
        for c in 0..k {
            assert_eq!(
                bits(ps_scalar.atb_col(c)),
                bits(ps_tiled.atb_col(c)),
                "tiled kernel bits diverge at m={m} c={c}"
            );
        }

        set_block_threshold_bytes(usize::MAX);
        let t_scalar = bencher.run(&format!("panel_scalar_m{m}"), || {
            std::hint::black_box(native.gram_panel(
                &store,
                &panel,
                CrossMode::Skip,
                NumericsMode::Exact,
            ))
        });
        set_block_threshold_bytes(1);
        let t_tiled = bencher.run(&format!("panel_tiled_m{m}"), || {
            std::hint::black_box(native.gram_panel(
                &store,
                &panel,
                CrossMode::Skip,
                NumericsMode::Exact,
            ))
        });
        let speedup = t_scalar.median_s / t_tiled.median_s;
        json.ns(&format!("panel_scalar_m{m}"), t_scalar.median_s);
        json.ns(&format!("panel_tiled_m{m}"), t_tiled.median_s);
        json.num(&format!("tiled_speedup_m{m}"), speedup);
        println!(
            "{:>8} {:>6} {:>4} | {:>12.0} {:>12.0} {:>7.2}x",
            m,
            ell,
            k,
            t_scalar.median_s * 1e9,
            t_tiled.median_s * 1e9,
            speedup
        );
        if speedup < 1.0 {
            println!(
                "WARN: tiled kernel slower than scalar at m={m} ({speedup:.2}x) — \
                 acceptance bar is ≥ 1x at m ∈ {{1e4, 1e5}}"
            );
        }
    }
    // leave the process with the default threshold, not a bench pin
    set_block_threshold_bytes(BLOCK_THRESHOLD_DEFAULT);
}

/// Exact-vs-fast error budget on the bench panel: the measured max |Δ|
/// the driver would assert, persisted next to the timing cells.
fn fast_budget_bench(json: &mut BenchJson) {
    use avi_scale::backend::store::{gram_panel_fast_seq, gram_panel_seq};
    println!("-- fast budget: f32 panel kernels vs the f64 reference --");
    let m = 100_000usize;
    let (ell, k) = (8usize, 8usize);
    let mut rng = Rng::new(47);
    let cols: Vec<Vec<f64>> = (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
    let store = ColumnStore::from_cols(&cols, 4);
    let mut panel = CandidatePanel::new_like(&store);
    for _ in 0..k {
        let c: Vec<f64> = (0..m).map(|_| rng.uniform() - 0.5).collect();
        panel.push_col(&c);
    }
    let exact = gram_panel_seq(&store, &panel, CrossMode::Lazy);
    let fast = gram_panel_fast_seq(&store, &panel, CrossMode::Lazy);
    let mut max_err = 0.0f64;
    let mut scale = 0.0f64;
    for c in 0..k {
        for j in 0..ell {
            scale = scale.max(exact.atb_col(c)[j].abs());
            max_err = max_err.max((fast.atb_col(c)[j] - exact.atb_col(c)[j]).abs());
        }
        scale = scale.max(exact.btb(c).abs());
        max_err = max_err.max((fast.btb(c) - exact.btb(c)).abs());
    }
    let budget = 1e-3 * scale.max(1.0);
    json.num("fast_max_abs_err", max_err);
    json.num("fast_err_budget", budget);
    println!("m={m} ell={ell} k={k}: max|Δ| = {max_err:.3e}, budget = {budget:.3e}");
    assert!(max_err <= budget, "fast panel kernels exceed the 1e-3 budget on benign data");
}

fn fit_bench(pool: &ThreadPool, json: &mut BenchJson) {
    println!("-- end-to-end: sharded OAVI fit, panel vs per-candidate --");
    let ds = synthetic_dataset(20_000, 11);
    let x = ds.class_matrix(0);
    let cfg = OaviConfig::cgavi_ihb(0.005);
    let backend = ShardedBackend::with_handle(pool.handle(), 4, 64);
    let d0 = pool.handle().batches_dispatched();
    let t = Timer::start();
    let legacy = Oavi::new(cfg).fit_with_backend_per_candidate(&x, &backend).unwrap();
    let legacy_s = t.secs();
    let d1 = pool.handle().batches_dispatched();
    let t = Timer::start();
    let panel = Oavi::new(cfg).fit_with_backend(&x, &backend).unwrap();
    let panel_s = t.secs();
    let d2 = pool.handle().batches_dispatched();
    // same model, attributable speedup
    assert_eq!(legacy.generators.len(), panel.generators.len());
    assert_eq!(legacy.o_terms.len(), panel.o_terms.len());
    json.ns("fit_percand", legacy_s);
    json.ns("fit_panel", panel_s);
    json.int("fit_percand_dispatches", d1 - d0);
    json.int("fit_panel_dispatches", d2 - d1);
    json.int("fit_panel_passes", panel.stats.panel_passes as u64);
    json.int("fit_cross_cache_hits", panel.stats.cross_cache_hits as u64);
    println!(
        "per-candidate: {:.3}s ({} dispatches)   panel: {:.3}s ({} dispatches, {} passes, \
         {} cross-cache hits)   speedup {:.2}x",
        legacy_s,
        d1 - d0,
        panel_s,
        d2 - d1,
        panel.stats.panel_passes,
        panel.stats.cross_cache_hits,
        legacy_s / panel_s
    );
}

fn main() {
    let bencher = Bencher::new(1, 5);
    let pool = ThreadPool::new(4);
    println!("== micro_gram_panel: degree-batched panels vs per-candidate loop ==");
    let mut json = BenchJson::new("micro_gram_panel");
    kernel_bench(&bencher, &pool, &mut json);
    tiled_ab_bench(&bencher, &mut json);
    fast_budget_bench(&mut json);
    fit_bench(&pool, &mut json);
    if let Err(e) = json.write() {
        eprintln!("(bench json write failed: {e})");
    }
}

//! Figure 2: training time of PCGAVI vs BPCGAVI over the number of
//! training samples (bank, htru, skin, synthetic; ψ = 0.005).
//!
//! Paper shape to check: BPCGAVI ≤ PCGAVI everywhere except possibly
//! skin-like data.  Scale via AVI_BENCH_SCALE / AVI_BENCH_RUNS env vars.

use avi_scale::bench::figures::{fig2_methods, training_time_sweep, SweepSpec};
use avi_scale::bench::report_figure;

fn main() {
    let mut spec = SweepSpec::quick();
    if let Ok(s) = std::env::var("AVI_BENCH_SCALE") {
        spec.scale = s.parse().unwrap_or(spec.scale);
    }
    if let Ok(r) = std::env::var("AVI_BENCH_RUNS") {
        spec.runs = r.parse().unwrap_or(spec.runs);
    }
    let blocks = training_time_sweep(&fig2_methods(), &spec).expect("sweep");
    for (ds, series) in &blocks {
        report_figure(&format!("fig2_{ds}"), "m", series);
    }
    // paper-shape summary: BPCGAVI vs PCGAVI at the largest m
    println!("\nshape check (largest m):");
    for (ds, series) in &blocks {
        let pcg = series[0].points.last().unwrap().1;
        let bpcg = series[1].points.last().unwrap().1;
        println!(
            "  {ds:<10} PCGAVI {pcg:.4}s  BPCGAVI {bpcg:.4}s  → {}",
            if bpcg <= pcg { "BPCG faster (paper shape)" } else { "PCG faster (skin-like exception)" }
        );
    }
}

//! Table 3: test error, hyperopt time, test time, |G|+|O|, degree, SPAR
//! for CGAVI-IHB+SVM, AGDAVI-IHB+SVM, BPCGAVI-WIHB+SVM, ABM+SVM, VCA+SVM
//! and the polynomial-kernel SVM across the six registry datasets.
//!
//! Scaled down by default (AVI_BENCH_SCALE / AVI_BENCH_SPLITS to grow).

use avi_scale::baselines::abm::AbmConfig;
use avi_scale::baselines::vca::VcaConfig;
use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::data::load_registry_dataset;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::oavi::OaviConfig;
use avi_scale::pipeline::report::{format_table, run_cell, Method, Protocol};

fn main() {
    let scale: f64 = std::env::var("AVI_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.015);
    let splits: usize = std::env::var("AVI_BENCH_SPLITS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2); // paper: 10
    let methods = [
        Method::Estimator(EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.005))),
        Method::Estimator(EstimatorConfig::Oavi(OaviConfig::agdavi_ihb(0.005))),
        Method::Estimator(EstimatorConfig::Oavi(OaviConfig::bpcgavi_wihb(0.005))),
        Method::Estimator(EstimatorConfig::Abm(AbmConfig::new(0.005))),
        Method::Estimator(EstimatorConfig::Vca(VcaConfig::new(0.005))),
        Method::KernelSvm,
    ];
    let pool = ThreadPool::default_size();
    let mut cells = Vec::new();
    for name in ["bank", "credit", "htru", "seeds", "skin", "spam"] {
        let ds = load_registry_dataset(name, scale, 9).expect("dataset");
        let protocol = Protocol {
            n_splits: splits,
            cv_folds: 3,
            psis: &[0.01, 0.005],
            lambdas: &[1e-2, 1e-3],
            ..Default::default()
        };
        for method in methods {
            let cell = run_cell(method, &ds, &protocol, &pool).expect("cell");
            println!(
                "[done] {:<22} {:<8} err={:.2}% hyper={:.2}s",
                cell.method,
                cell.dataset,
                cell.error_mean * 100.0,
                cell.hyper_secs
            );
            cells.push(cell);
        }
    }
    println!("\n{}", format_table(&cells));
    let rows: Vec<Vec<f64>> = cells
        .iter()
        .map(|c| {
            vec![c.error_mean, c.error_std, c.hyper_secs, c.test_secs, c.size, c.degree, c.spar]
        })
        .collect();
    let _ = avi_scale::data::csvio::write_csv(
        std::path::Path::new("target/bench_results/table3.csv"),
        &["error_mean", "error_std", "hyper_secs", "test_secs", "size", "degree", "spar"],
        &rows,
    );
}

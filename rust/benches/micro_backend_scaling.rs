//! Micro: data-plane scaling — gram_stats and transform_abs per-call ns
//! over m ∈ {1e4, 1e5, 1e6} × shards ∈ {1, 2, 4, 8}, NativeBackend
//! (sequential shard reduction) vs ShardedBackend (thread-pool map) —
//! plus the persistent-pool acceptance gates (ISSUE 3):
//!
//! * **dispatch overhead** — per-call job hand-off through the
//!   persistent pool vs. the old per-call scoped spawn/join baseline;
//!   the persistent column must be smaller.
//! * **small-batch transform** — m = 1k sharded `transform_abs` on a
//!   ≥ 4-worker pool: the calibrated adaptive threshold must let it run
//!   parallel (the old hard-coded 256k-madd gate kept it sequential).
//!
//! This is the hot-path regression tracker for the sharded column-store
//! data plane: the paper's "linear in m" becomes "linear in m / cores"
//! exactly when the `sharded` column shows ≥ 2× over `native` at
//! m = 1e6, shards = 4 on a multi-core host (ISSUE 1 acceptance bar).
//! Results are asserted bit-identical before timing so a perf reading
//! can never come from divergent arithmetic.  Every cell also lands in
//! `target/bench_results/BENCH_backend_scaling.json` for
//! `scripts/bench_gate.sh` to diff across commits.

use avi_scale::backend::{ColumnStore, ComputeBackend, NativeBackend, ShardedBackend};
use avi_scale::bench::{report_figure, BenchJson, Bencher, Series};
use avi_scale::coordinator::pool::{Job, ThreadPool};
use avi_scale::linalg::dense::Matrix;
use avi_scale::util::rng::Rng;

/// The pre-ISSUE-3 baseline: spawn + join scoped threads on every call.
fn scoped_spawn_noop(jobs: usize) {
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {});
        }
    });
}

fn dispatch_overhead_bench(bencher: &Bencher, json: &mut BenchJson) {
    println!("-- dispatch overhead (per call, 4 no-op jobs) --");
    let pool = ThreadPool::new(4);
    let handle = pool.handle();
    let noop_jobs = || -> Vec<Job<'static, ()>> {
        (0..4).map(|_| Box::new(|| ()) as Job<'static, ()>).collect()
    };
    handle.run_all(noop_jobs()); // warm the workers
    let scoped = bencher.run("dispatch_scoped_spawn", || scoped_spawn_noop(4));
    // the true cross-thread hand-off (push → wakeup → pop → notify),
    // helping disabled — this is the number the old scoped spawn/join is
    // compared against (ISSUE 3 acceptance) and what adaptive_min_work
    // calibrates from
    let handoff = bencher.run("dispatch_pool_handoff", || handle.dispatch_to_workers(4));
    // the submitter's inline helping fast path (what a run_all caller
    // actually pays when workers are busy) — reported separately, NOT
    // the acceptance number
    let inline = bencher.run("dispatch_pool_inline", || handle.run_all(noop_jobs()));
    println!(
        "scoped_spawn = {:.0} ns/call   pool_handoff = {:.0} ns/call ({:.1}x lower)   \
         pool_inline_helping = {:.0} ns/call",
        scoped.median_s * 1e9,
        handoff.median_s * 1e9,
        scoped.median_s / handoff.median_s,
        inline.median_s * 1e9
    );
    println!(
        "adaptive_min_work = {} madds/shard (was hard-coded {})",
        pool.adaptive_min_work(),
        256 * 1024
    );
    json.ns("dispatch_scoped", scoped.median_s);
    json.ns("dispatch_handoff", handoff.median_s);
    json.ns("dispatch_inline", inline.median_s);
    json.int("adaptive_min_work", pool.adaptive_min_work() as u64);
    let mut series = Series::new("dispatch_ns".to_string());
    series.push_obs(0.0, &[scoped.median_s]);
    series.push_obs(1.0, &[handoff.median_s]);
    series.push_obs(2.0, &[inline.median_s]);
    report_figure("micro_dispatch_overhead", "impl(0=scoped,1=handoff,2=inline)", &[series]);
}

fn small_batch_transform_bench(bencher: &Bencher, rng: &mut Rng, json: &mut BenchJson) {
    // serving-sized batch: m = 1k, 4 shards, 4-worker pool
    let (m, ell, g, k) = (1000usize, 16usize, 8usize, 4usize);
    println!("-- small-batch transform (m={m}, ell={ell}, g={g}, shards={k}) --");
    let cols: Vec<Vec<f64>> =
        (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
    let store = ColumnStore::from_cols(&cols, k);
    let mut c = Matrix::zeros(ell, g);
    let mut u = Matrix::zeros(m, g);
    for j in 0..ell {
        for kk in 0..g {
            c.set(j, kk, rng.normal());
        }
    }
    for i in 0..m {
        for kk in 0..g {
            u.set(i, kk, rng.normal());
        }
    }
    let sharded = ShardedBackend::new(4);
    let work_per_shard = ell * g * (m / k);
    let threshold = sharded.min_work_threshold();
    let engaged = work_per_shard >= threshold;
    // ISSUE 3 acceptance: a 1k-row batch on a >= 4-worker pool should no
    // longer fall back to the sequential path.  The threshold is a live
    // calibration, so report loudly rather than abort the whole bench on
    // a loaded machine where dispatch measured slow.
    if !engaged {
        println!(
            "WARN: small batch fell back to sequential \
             (work/shard {work_per_shard} < threshold {threshold}) — \
             acceptance bar NOT met on this host/run"
        );
    }
    let forced = ShardedBackend::new(4).with_min_work(0);
    let tn = NativeBackend.transform_abs(&store, &c, &u);
    for backend in [&sharded, &forced] {
        let ts = backend.transform_abs(&store, &c, &u);
        for (a, b) in tn.data().iter().zip(ts.data().iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "small-batch transform diverged");
        }
    }
    let native = bencher.run("small_tr_native", || NativeBackend.transform_abs(&store, &c, &u));
    let policy = bencher.run("small_tr_sharded", || sharded.transform_abs(&store, &c, &u));
    let parallel = bencher.run("small_tr_forced", || forced.transform_abs(&store, &c, &u));
    json.ns("small_tr_native", native.median_s);
    json.ns("small_tr_sharded", policy.median_s);
    json.ns("small_tr_forced", parallel.median_s);
    json.int("small_tr_parallel_engaged", engaged as u64);
    println!(
        "parallel engaged = {engaged} (work/shard {work_per_shard} vs threshold {threshold})"
    );
    println!(
        "tr_native = {:.0} ns   tr_sharded(policy) = {:.0} ns ({:.2}x)   \
         tr_sharded(forced-parallel) = {:.0} ns ({:.2}x)",
        native.median_s * 1e9,
        policy.median_s * 1e9,
        native.median_s / policy.median_s,
        parallel.median_s * 1e9,
        native.median_s / parallel.median_s
    );
}

fn main() {
    let bencher = Bencher::new(1, 5);
    let mut rng = Rng::new(23);
    let ell = 16usize;
    let g = 8usize;
    let mut json = BenchJson::new("backend_scaling");

    dispatch_overhead_bench(&bencher, &mut json);
    small_batch_transform_bench(&bencher, &mut rng, &mut json);

    let mut gram_series: Vec<Series> = Vec::new();
    let mut tr_series: Vec<Series> = Vec::new();

    println!(
        "{:>9} {:>7} {:>15} {:>15} {:>8}   {:>15} {:>15} {:>8}",
        "m", "shards", "gram_native_ns", "gram_shard_ns", "speedup", "tr_native_ns",
        "tr_shard_ns", "speedup"
    );
    for &m in &[10_000usize, 100_000, 1_000_000] {
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let mut c = Matrix::zeros(ell, g);
        let mut u = Matrix::zeros(m, g);
        for j in 0..ell {
            for k in 0..g {
                c.set(j, k, rng.normal());
            }
        }
        for i in 0..m {
            for k in 0..g {
                u.set(i, k, rng.normal());
            }
        }
        let mut gram_native = Series::new(format!("gram_native_m{m}"));
        let mut gram_shard = Series::new(format!("gram_sharded_m{m}"));
        let mut tr_native = Series::new(format!("tr_native_m{m}"));
        let mut tr_shard = Series::new(format!("tr_sharded_m{m}"));
        for &k in &[1usize, 2, 4, 8] {
            let store = ColumnStore::from_cols(&cols, k);
            let sharded = ShardedBackend::new(k);

            // correctness gate before timing: bit-identical per shard count
            let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
            let (atb_s, btb_s) = sharded.gram_stats(&store, &b);
            assert_eq!(btb_n.to_bits(), btb_s.to_bits(), "btb diverged at m={m} k={k}");
            for (a, s) in atb_n.iter().zip(atb_s.iter()) {
                assert_eq!(a.to_bits(), s.to_bits(), "atb diverged at m={m} k={k}");
            }

            let gn = bencher.run("gram_native", || NativeBackend.gram_stats(&store, &b));
            let gs = bencher.run("gram_sharded", || sharded.gram_stats(&store, &b));
            let tn = bencher.run("tr_native", || NativeBackend.transform_abs(&store, &c, &u));
            let ts = bencher.run("tr_sharded", || sharded.transform_abs(&store, &c, &u));
            println!(
                "{m:>9} {k:>7} {:>15.0} {:>15.0} {:>7.2}x   {:>15.0} {:>15.0} {:>7.2}x",
                gn.median_s * 1e9,
                gs.median_s * 1e9,
                gn.median_s / gs.median_s,
                tn.median_s * 1e9,
                ts.median_s * 1e9,
                tn.median_s / ts.median_s
            );
            gram_native.push_obs(k as f64, &[gn.median_s]);
            gram_shard.push_obs(k as f64, &[gs.median_s]);
            tr_native.push_obs(k as f64, &[tn.median_s]);
            tr_shard.push_obs(k as f64, &[ts.median_s]);
            json.ns(&format!("gram_native_m{m}_s{k}"), gn.median_s);
            json.ns(&format!("gram_sharded_m{m}_s{k}"), gs.median_s);
            json.ns(&format!("tr_native_m{m}_s{k}"), tn.median_s);
            json.ns(&format!("tr_sharded_m{m}_s{k}"), ts.median_s);
        }
        gram_series.push(gram_native);
        gram_series.push(gram_shard);
        tr_series.push(tr_native);
        tr_series.push(tr_shard);
    }
    report_figure("micro_backend_scaling_gram", "shards", &gram_series);
    report_figure("micro_backend_scaling_transform", "shards", &tr_series);
    if let Err(e) = json.write() {
        eprintln!("(bench json write failed: {e})");
    }
}

//! Micro: data-plane scaling — gram_stats and transform_abs per-call ns
//! over m ∈ {1e4, 1e5, 1e6} × shards ∈ {1, 2, 4, 8}, NativeBackend
//! (sequential shard reduction) vs ShardedBackend (thread-pool map).
//!
//! This is the hot-path regression tracker for the sharded column-store
//! data plane: the paper's "linear in m" becomes "linear in m / cores"
//! exactly when the `sharded` column shows ≥ 2× over `native` at
//! m = 1e6, shards = 4 on a multi-core host (ISSUE 1 acceptance bar).
//! Results are asserted bit-identical before timing so a perf reading
//! can never come from divergent arithmetic.

use avi_scale::backend::{ColumnStore, ComputeBackend, NativeBackend, ShardedBackend};
use avi_scale::bench::{report_figure, Bencher, Series};
use avi_scale::linalg::dense::Matrix;
use avi_scale::util::rng::Rng;

fn main() {
    let bencher = Bencher::new(1, 5);
    let mut rng = Rng::new(23);
    let ell = 16usize;
    let g = 8usize;

    let mut gram_series: Vec<Series> = Vec::new();
    let mut tr_series: Vec<Series> = Vec::new();

    println!(
        "{:>9} {:>7} {:>15} {:>15} {:>8}   {:>15} {:>15} {:>8}",
        "m", "shards", "gram_native_ns", "gram_shard_ns", "speedup", "tr_native_ns",
        "tr_shard_ns", "speedup"
    );
    for &m in &[10_000usize, 100_000, 1_000_000] {
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let mut c = Matrix::zeros(ell, g);
        let mut u = Matrix::zeros(m, g);
        for j in 0..ell {
            for k in 0..g {
                c.set(j, k, rng.normal());
            }
        }
        for i in 0..m {
            for k in 0..g {
                u.set(i, k, rng.normal());
            }
        }
        let mut gram_native = Series::new(format!("gram_native_m{m}"));
        let mut gram_shard = Series::new(format!("gram_sharded_m{m}"));
        let mut tr_native = Series::new(format!("tr_native_m{m}"));
        let mut tr_shard = Series::new(format!("tr_sharded_m{m}"));
        for &k in &[1usize, 2, 4, 8] {
            let store = ColumnStore::from_cols(&cols, k);
            let sharded = ShardedBackend::new(k);

            // correctness gate before timing: bit-identical per shard count
            let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
            let (atb_s, btb_s) = sharded.gram_stats(&store, &b);
            assert_eq!(btb_n.to_bits(), btb_s.to_bits(), "btb diverged at m={m} k={k}");
            for (a, s) in atb_n.iter().zip(atb_s.iter()) {
                assert_eq!(a.to_bits(), s.to_bits(), "atb diverged at m={m} k={k}");
            }

            let gn = bencher.run("gram_native", || NativeBackend.gram_stats(&store, &b));
            let gs = bencher.run("gram_sharded", || sharded.gram_stats(&store, &b));
            let tn = bencher.run("tr_native", || NativeBackend.transform_abs(&store, &c, &u));
            let ts = bencher.run("tr_sharded", || sharded.transform_abs(&store, &c, &u));
            println!(
                "{m:>9} {k:>7} {:>15.0} {:>15.0} {:>7.2}x   {:>15.0} {:>15.0} {:>7.2}x",
                gn.median_s * 1e9,
                gs.median_s * 1e9,
                gn.median_s / gs.median_s,
                tn.median_s * 1e9,
                ts.median_s * 1e9,
                tn.median_s / ts.median_s
            );
            gram_native.push_obs(k as f64, &[gn.median_s]);
            gram_shard.push_obs(k as f64, &[gs.median_s]);
            tr_native.push_obs(k as f64, &[tn.median_s]);
            tr_shard.push_obs(k as f64, &[ts.median_s]);
        }
        gram_series.push(gram_native);
        gram_series.push(gram_shard);
        tr_series.push(tr_native);
        tr_series.push(tr_shard);
    }
    report_figure("micro_backend_scaling_gram", "shards", &gram_series);
    report_figure("micro_backend_scaling_transform", "shards", &tr_series);
}

//! Micro: the persistence envelope's two codecs head to head (ISSUE 9
//! satellite) — JSON (`{:e}` shortest-round-trip floats) vs the binary
//! AVIB artifact codec (raw little-endian f64 bits) — at three trained
//! pipeline sizes.
//!
//! Both directions are bitwise-gated before any timing: the binary
//! round trip must reproduce the JSON-loaded model's transform bits, so
//! a perf or size reading can never come from divergent contents.  The
//! acceptance bar asserted here is the ISSUE 9 one: the binary artifact
//! is strictly smaller than the JSON envelope at every size.
//!
//! Cells land in `target/bench_results/BENCH_persist_codec.json`
//! (`{size}_{json|bin}_{encode|decode}_ns`, `{size}_{json|bin}_bytes`,
//! `{size}_bin_over_json`) for `scripts/bench_gate.sh` to diff across
//! commits.

use avi_scale::artifact;
use avi_scale::bench::{BenchJson, Bencher};
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::estimator::{persist, EstimatorConfig};
use avi_scale::oavi::OaviConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::{train_pipeline, PipelineConfig, PipelineModel};
use avi_scale::svm::linear::LinearSvmConfig;

fn trained(m: usize, psi: f64, seed: u64) -> PipelineModel {
    let ds = synthetic_dataset(m, seed);
    let cfg = PipelineConfig {
        estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(psi)),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    train_pipeline(&cfg, &ds).expect("bench pipeline trains")
}

fn main() {
    let bencher = Bencher::new(2, 9);
    println!("== micro_persist_codec: JSON envelope vs binary AVIB artifact ==");
    let mut json = BenchJson::new("persist_codec");
    println!(
        "{:>8} | {:>12} {:>12} {:>8} | {:>12} {:>12} | {:>12} {:>12}",
        "size",
        "json_bytes",
        "bin_bytes",
        "ratio",
        "json_enc_ns",
        "bin_enc_ns",
        "json_dec_ns",
        "bin_dec_ns"
    );
    // three model sizes: sample count and vanishing tolerance together
    // drive |G|+|O| and therefore the float payload the codecs carry
    for (tag, m, psi) in [
        ("small", 200usize, 0.05),
        ("medium", 600, 0.01),
        ("large", 1500, 0.005),
    ] {
        let model = trained(m, psi, 9 + m as u64);
        let json_bytes = persist::pipeline_to_json(&model).into_bytes();
        let bin_bytes = artifact::encode_pipeline(&model).expect("binary encode");

        // bitwise gate: the two codecs must describe the same model
        let from_json = persist::pipeline_from_bytes(&json_bytes).unwrap();
        let from_bin = artifact::decode_pipeline(&bin_bytes).unwrap();
        let ds = synthetic_dataset(64, 77 + m as u64);
        let backend = avi_scale::backend::NativeBackend;
        let (la, sa) = from_json.predict_scores_with_backend(&ds.x, &backend);
        let (lb, sb) = from_bin.predict_scores_with_backend(&ds.x, &backend);
        assert_eq!(la, lb, "codec round trips disagree on labels at size {tag}");
        for (ra, rb) in sa.iter().zip(&sb) {
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(ra), bits(rb), "score bits diverge at size {tag}");
        }

        // ISSUE 9 acceptance bar: binary strictly smaller than JSON
        assert!(
            bin_bytes.len() < json_bytes.len(),
            "binary artifact ({} B) must be smaller than JSON ({} B) at size {tag}",
            bin_bytes.len(),
            json_bytes.len()
        );

        let t_json_enc = bencher.run(&format!("{tag}_json_encode"), || {
            std::hint::black_box(persist::pipeline_to_json(&model));
        });
        let t_bin_enc = bencher.run(&format!("{tag}_bin_encode"), || {
            std::hint::black_box(artifact::encode_pipeline(&model).unwrap());
        });
        let t_json_dec = bencher.run(&format!("{tag}_json_decode"), || {
            std::hint::black_box(persist::pipeline_from_bytes(&json_bytes).unwrap());
        });
        let t_bin_dec = bencher.run(&format!("{tag}_bin_decode"), || {
            std::hint::black_box(artifact::decode_pipeline(&bin_bytes).unwrap());
        });

        json.ns(&format!("{tag}_json_encode"), t_json_enc.median_s);
        json.ns(&format!("{tag}_bin_encode"), t_bin_enc.median_s);
        json.ns(&format!("{tag}_json_decode"), t_json_dec.median_s);
        json.ns(&format!("{tag}_bin_decode"), t_bin_dec.median_s);
        json.int(&format!("{tag}_json_bytes"), json_bytes.len() as u64);
        json.int(&format!("{tag}_bin_bytes"), bin_bytes.len() as u64);
        json.num(
            &format!("{tag}_bin_over_json"),
            bin_bytes.len() as f64 / json_bytes.len() as f64,
        );
        println!(
            "{:>8} | {:>12} {:>12} {:>7.2}x | {:>12.0} {:>12.0} | {:>12.0} {:>12.0}",
            tag,
            json_bytes.len(),
            bin_bytes.len(),
            json_bytes.len() as f64 / bin_bytes.len() as f64,
            t_json_enc.median_s * 1e9,
            t_bin_enc.median_s * 1e9,
            t_json_dec.median_s * 1e9,
            t_bin_dec.median_s * 1e9,
        );
    }
    if let Err(e) = json.write() {
        eprintln!("(bench json write failed: {e})");
    }
}

//! Micro: native vs PJRT (AOT Pallas artifact) backends on the two hot
//! paths — gram_stats and the (FT) transform.  Requires `make artifacts`;
//! skips with a message otherwise.

use std::sync::Arc;

use avi_scale::backend::{ComputeBackend, NativeBackend};
use avi_scale::bench::{report_figure, Bencher, Series};
use avi_scale::linalg::dense::Matrix;
use avi_scale::runtime::{PjrtRuntime, XlaBackend};
use avi_scale::util::rng::Rng;

fn main() {
    let rt = match PjrtRuntime::load(std::path::Path::new("artifacts")) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("SKIP micro_runtime: {e}");
            return;
        }
    };
    let xla = XlaBackend::new(rt);
    let native = NativeBackend;
    let bencher = Bencher::new(1, 5);
    let mut rng = Rng::new(11);

    let mut native_gram = Series::new("native_gram");
    let mut xla_gram = Series::new("xla_gram");
    for &m in &[4096usize, 16384, 65536] {
        let ell = 32;
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let sn = bencher.run("native", || native.gram_stats(&cols, &b));
        let sx = bencher.run("xla", || xla.gram_stats(&cols, &b));
        println!(
            "gram m={m:>6} ell={ell}: native {:>9.1}us  xla {:>9.1}us ({:.1}x)",
            sn.median_s * 1e6,
            sx.median_s * 1e6,
            sx.median_s / sn.median_s
        );
        native_gram.push_obs(m as f64, &[sn.median_s]);
        xla_gram.push_obs(m as f64, &[sx.median_s]);
    }
    report_figure("micro_runtime_gram", "m", &[native_gram, xla_gram]);

    let mut native_tr = Series::new("native_transform");
    let mut xla_tr = Series::new("xla_transform");
    for &m in &[4096usize, 16384] {
        let (ell, g) = (32usize, 24usize);
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let mut c = Matrix::zeros(ell, g);
        let mut u = Matrix::zeros(m, g);
        for j in 0..ell {
            for k in 0..g {
                c.set(j, k, rng.normal());
            }
        }
        for i in 0..m {
            for k in 0..g {
                u.set(i, k, rng.normal());
            }
        }
        let sn = bencher.run("native", || native.transform_abs(&cols, &c, &u));
        let sx = bencher.run("xla", || xla.transform_abs(&cols, &c, &u));
        println!(
            "transform m={m:>6}: native {:>9.1}us  xla {:>9.1}us ({:.1}x)",
            sn.median_s * 1e6,
            sx.median_s * 1e6,
            sx.median_s / sn.median_s
        );
        native_tr.push_obs(m as f64, &[sn.median_s]);
        xla_tr.push_obs(m as f64, &[sx.median_s]);
    }
    report_figure("micro_runtime_transform", "m", &[native_tr, xla_tr]);
}

//! Micro: native vs PJRT (AOT Pallas artifact) backends on the two hot
//! paths — gram_stats and the (FT) transform — plus the
//! `transform_branch_gate` that decides the zero-skip question (runs
//! without artifacts).  The backend comparison requires `make artifacts`;
//! it skips with a message otherwise.

use std::sync::Arc;

use avi_scale::backend::{ColumnStore, ComputeBackend, NativeBackend};
use avi_scale::bench::{report_figure, Bencher, Series};
use avi_scale::linalg::dense::Matrix;
use avi_scale::runtime::{PjrtRuntime, XlaBackend};
use avi_scale::util::rng::Rng;

/// Bench gate for the historical `if a_ij == 0.0 { continue; }` skip in
/// the transform kernel.  Both variants are reproduced here over plain
/// columns so the comparison is exactly the branch, nothing else.  The
/// production kernel (`backend::store::transform_block`) is branchless —
/// see the verdict comment in `backend/mod.rs`; re-run this gate before
/// reintroducing the skip.
fn transform_branch_gate(bencher: &Bencher, rng: &mut Rng) {
    let (m, ell, g) = (65_536usize, 32usize, 24usize);
    println!("--- transform_branch_gate (m={m}, ell={ell}, g={g}) ---");
    for &(label, density) in &[("dense", 1.0f64), ("half-zero", 0.5), ("mostly-zero", 0.05)] {
        let cols: Vec<Vec<f64>> = (0..ell)
            .map(|_| {
                (0..m)
                    .map(|_| if rng.uniform() < density { rng.uniform() } else { 0.0 })
                    .collect()
            })
            .collect();
        let c: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..g).map(|_| rng.normal()).collect()).collect();
        let u: Vec<f64> = (0..m * g).map(|_| rng.normal()).collect();

        let branchy = || {
            let mut out = u.clone();
            for (j, col) in cols.iter().enumerate() {
                let crow = &c[j];
                for (i, &a_ij) in col.iter().enumerate() {
                    if a_ij == 0.0 {
                        continue;
                    }
                    let orow = &mut out[i * g..(i + 1) * g];
                    for (o, ck) in orow.iter_mut().zip(crow.iter()) {
                        *o += a_ij * ck;
                    }
                }
            }
            for v in out.iter_mut() {
                *v = v.abs();
            }
            out
        };
        let branchless = || {
            let mut out = u.clone();
            for (j, col) in cols.iter().enumerate() {
                let crow = &c[j];
                for (i, &a_ij) in col.iter().enumerate() {
                    let orow = &mut out[i * g..(i + 1) * g];
                    for (o, ck) in orow.iter_mut().zip(crow.iter()) {
                        *o += a_ij * ck;
                    }
                }
            }
            for v in out.iter_mut() {
                *v = v.abs();
            }
            out
        };
        let sb = bencher.run("branchy", branchy);
        let sl = bencher.run("branchless", branchless);
        println!(
            "{label:>12}: branchy {:>9.1}us  branchless {:>9.1}us  (branchless {:.2}x)",
            sb.median_s * 1e6,
            sl.median_s * 1e6,
            sb.median_s / sl.median_s
        );
    }
    println!("(verdict recorded in rust/src/backend/mod.rs)");
}

fn main() {
    let bencher = Bencher::new(1, 5);
    let mut rng = Rng::new(11);

    // runs regardless of artifacts: the zero-skip decision gate
    transform_branch_gate(&bencher, &mut rng);

    let rt = match PjrtRuntime::load(std::path::Path::new("artifacts")) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("SKIP micro_runtime backend comparison: {e}");
            return;
        }
    };
    let xla = XlaBackend::new(rt);
    let native = NativeBackend;

    let mut native_gram = Series::new("native_gram");
    let mut xla_gram = Series::new("xla_gram");
    for &m in &[4096usize, 16384, 65536] {
        let ell = 32;
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let store = ColumnStore::from_cols(&cols, 1);
        let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let sn = bencher.run("native", || native.gram_stats(&store, &b));
        let sx = bencher.run("xla", || xla.gram_stats(&store, &b));
        println!(
            "gram m={m:>6} ell={ell}: native {:>9.1}us  xla {:>9.1}us ({:.1}x)",
            sn.median_s * 1e6,
            sx.median_s * 1e6,
            sx.median_s / sn.median_s
        );
        native_gram.push_obs(m as f64, &[sn.median_s]);
        xla_gram.push_obs(m as f64, &[sx.median_s]);
    }
    report_figure("micro_runtime_gram", "m", &[native_gram, xla_gram]);

    let mut native_tr = Series::new("native_transform");
    let mut xla_tr = Series::new("xla_transform");
    for &m in &[4096usize, 16384] {
        let (ell, g) = (32usize, 24usize);
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let store = ColumnStore::from_cols(&cols, 1);
        let mut c = Matrix::zeros(ell, g);
        let mut u = Matrix::zeros(m, g);
        for j in 0..ell {
            for k in 0..g {
                c.set(j, k, rng.normal());
            }
        }
        for i in 0..m {
            for k in 0..g {
                u.set(i, k, rng.normal());
            }
        }
        let sn = bencher.run("native", || native.transform_abs(&store, &c, &u));
        let sx = bencher.run("xla", || xla.transform_abs(&store, &c, &u));
        println!(
            "transform m={m:>6}: native {:>9.1}us  xla {:>9.1}us ({:.1}x)",
            sn.median_s * 1e6,
            sx.median_s * 1e6,
            sx.median_s / sn.median_s
        );
        native_tr.push_obs(m as f64, &[sn.median_s]);
        xla_tr.push_obs(m as f64, &[sx.median_s]);
    }
    report_figure("micro_runtime_transform", "m", &[native_tr, xla_tr]);
}

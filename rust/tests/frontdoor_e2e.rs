//! Front-door end-to-end: spawn the built `avi-scale` binary with
//! `serve --listen`, speak the framed wire protocol over a real TCP
//! socket, and check every ISSUE-8 serving contract from outside the
//! process:
//!
//! * network scores are **bitwise identical** to the in-process
//!   [`TransformService`] on the same persisted model;
//! * malformed, oversized, rate-limited, and NaN-bearing traffic gets
//!   typed rejections — the server never panics and never hangs a peer;
//! * `--tenant` namespacing isolates routes (the bare key 404s);
//! * a silent peer is reaped by the read deadline;
//! * a `Shutdown` frame drains the in-flight batch before the process
//!   exits and prints its `RouterReport` with the wire counters.
//!
//! Plus the ISSUE-9 model control plane, against the same live server
//! (`--artifact-dir`): a binary artifact pushed over the wire,
//! activated, and served **bitwise identically** to in-process
//! prediction; a corrupted push refused with a typed `checksum_mismatch`
//! and never routable; garbage with an honest checksum refused as
//! `bad_artifact`; pulls returning the exact pushed bytes; and control
//! ops rate-limited under their own `model-control/<key>` buckets.
//!
//! One server instance serves every scenario; the token budget is
//! arranged so each outcome is deterministic (`--rate-limit 0` never
//! refills, so `--burst 3` grants route `acme/m` exactly three
//! admissions, and the later scenarios draw on fresh routes/buckets).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use avi_scale::artifact;
use avi_scale::backend::NativeBackend;
use avi_scale::coordinator::service::{ServeConfig, ServeRequest, TransformService};
use avi_scale::coordinator::wire::{
    self, ControlOutcome, FrameKind, PullOutcome, WireClient, WireOutcome,
};
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::estimator::{persist, EstimatorConfig};
use avi_scale::linalg::dense::Matrix;
use avi_scale::oavi::OaviConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::{train_pipeline, PipelineConfig};
use avi_scale::svm::linear::LinearSvmConfig;

/// Kill the server on drop so a failed assertion can't leak a process
/// that outlives the test run.
struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// `"key": N` out of the report JSON (the counters are flat u64 cells).
fn json_counter(text: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\": ");
    let pos = text.find(&pat).unwrap_or_else(|| panic!("missing {pat} in:\n{text}"));
    let rest = &text[pos + pat.len()..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().unwrap_or_else(|_| panic!("bad counter {key} in:\n{text}"))
}

#[test]
fn front_door_end_to_end() {
    // -- persist a model for the server to load --------------------------
    let dir = std::env::temp_dir().join(format!("avi_frontdoor_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let train = synthetic_dataset(300, 71);
    let cfg = PipelineConfig {
        estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01)),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    let model = train_pipeline(&cfg, &train).unwrap();
    let path = dir.join("model.json");
    persist::save(&model, &path).unwrap();

    // in-process reference on the same persisted bytes the server loads
    let loaded = Arc::new(persist::load(&path).unwrap());
    let svc = TransformService::start(loaded, ServeConfig::default());
    let ds = synthetic_dataset(64, 72);
    let rows: Vec<Vec<f64>> = (0..8).map(|i| ds.x.row(i).to_vec()).collect();
    let reference = svc.submit(ServeRequest::batch(rows.clone())).answer().unwrap();

    // -- spawn the server -----------------------------------------------
    let spec = format!("m@v1={p},aux@v1={p}", p = path.display());
    let child = Command::new(env!("CARGO_BIN_EXE_avi-scale"))
        .args([
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--model",
            &spec,
            "--tenant",
            "acme",
            "--scale",
            "0.001",
            "--rate-limit",
            "0",
            "--burst",
            "3",
            "--read-timeout-ms",
            "1000",
            "--max-frame-kb",
            "256",
            "--artifact-dir",
            &dir.join("store").display().to_string(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn avi-scale serve --listen");
    let mut child = KillOnDrop(child);
    let mut stdout = BufReader::new(child.0.stdout.take().unwrap());
    let addr = loop {
        let mut line = String::new();
        assert!(
            stdout.read_line(&mut line).unwrap() > 0,
            "server exited before printing its address"
        );
        if let Some(rest) = line.trim().strip_prefix("listening = ") {
            break rest.to_string();
        }
    };

    // -- happy path: bitwise identity over the wire ----------------------
    let mut client = WireClient::connect(&addr).unwrap();
    let answer = client
        .request("acme/m", &ServeRequest::batch(rows.clone()))
        .unwrap()
        .answer()
        .unwrap();
    assert_eq!(answer.key, "acme/m");
    assert_eq!(answer.version, "v1");
    assert_eq!(answer.predictions.len(), reference.predictions.len());
    for (a, b) in answer.predictions.iter().zip(&reference.predictions) {
        assert_eq!(a.label, b.label);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.scores), bits(&b.scores), "network scores must be bit-identical");
    }

    // -- tenant isolation: the bare key is not a route -------------------
    match client.request("m", &ServeRequest::row(ds.x.row(0).to_vec())).unwrap() {
        WireOutcome::Rejected { reason, .. } => assert_eq!(reason, "unknown_route"),
        other => panic!("bare key must 404 under --tenant, got {other:?}"),
    }

    // -- a NaN row is rejected at admission, never panics a worker -------
    let mut poisoned = ds.x.row(1).to_vec();
    poisoned[1] = f64::NAN;
    match client.request("acme/m", &ServeRequest::row(poisoned)).unwrap() {
        WireOutcome::Rejected { reason, detail } => {
            assert_eq!(reason, "non_finite");
            assert!(detail.contains("col 1"), "{detail}");
        }
        other => panic!("expected non_finite, got {other:?}"),
    }

    // -- deadline 0 expires deterministically ----------------------------
    let req = ServeRequest::row(ds.x.row(2).to_vec()).with_deadline(Duration::ZERO);
    match client.request("acme/m", &req).unwrap() {
        WireOutcome::Rejected { reason, .. } => assert_eq!(reason, "deadline_expired"),
        other => panic!("expected deadline_expired, got {other:?}"),
    }

    // -- token budget spent (3 admissions): rate limit turns us away -----
    for _ in 0..2 {
        match client.request("acme/m", &ServeRequest::row(ds.x.row(3).to_vec())).unwrap() {
            WireOutcome::Rejected { reason, .. } => assert_eq!(reason, "rate_limited"),
            other => panic!("expected rate_limited, got {other:?}"),
        }
    }
    drop(client);

    // -- model control plane: push a binary artifact, activate it, and
    //    serve it bitwise identically to in-process prediction ----------
    let train2 = synthetic_dataset(300, 73);
    let cfg2 = PipelineConfig {
        estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.02)),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    let model2 = train_pipeline(&cfg2, &train2).unwrap();
    let artifact_bytes = artifact::encode_pipeline(&model2).unwrap();
    let mut deployer = WireClient::connect(&addr).unwrap();
    let ack = deployer
        .push_model("m2", "v1", &artifact_bytes, false)
        .unwrap()
        .ack()
        .unwrap();
    assert_eq!(ack.key, "acme/m2", "push must land under the server's tenant");
    assert_eq!(ack.bytes, artifact_bytes.len() as u64);
    assert_eq!(ack.checksum, artifact::fnv64(&artifact_bytes));
    deployer.activate_model("m2", "v1").unwrap().ack().unwrap();

    let mut probe = Matrix::zeros(rows.len(), ds.x.cols());
    for (i, row) in rows.iter().enumerate() {
        for (j, v) in row.iter().enumerate() {
            probe.set(i, j, *v);
        }
    }
    let (labels2, scores2) = model2.predict_scores_with_backend(&probe, &NativeBackend);
    let answer = deployer
        .request("acme/m2", &ServeRequest::batch(rows.clone()))
        .unwrap()
        .answer()
        .unwrap();
    assert_eq!(answer.key, "acme/m2");
    assert_eq!(answer.version, "v1");
    for (i, p) in answer.predictions.iter().enumerate() {
        assert_eq!(p.label, labels2[i]);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(&p.scores),
            bits(&scores2[i]),
            "pushed+activated model must serve bit-identical scores"
        );
    }

    // pulling returns the exact bytes that were pushed (checksum
    // re-verified on both ends)
    let pulled = deployer.pull_model("m2", None).unwrap().model().unwrap();
    assert_eq!(pulled.key, "acme/m2");
    assert_eq!(pulled.version, "v1");
    assert_eq!(pulled.artifact, artifact_bytes);

    // the model-control bucket for this key (burst 3: push + activate +
    // pull) is now spent — control ops are rate-limited independently of
    // the data plane, which answered acme/m2 above just fine
    match deployer.pull_model("m2", None).unwrap() {
        PullOutcome::Rejected { reason, .. } => assert_eq!(reason, "rate_limited"),
        other => panic!("expected rate_limited control op, got {other:?}"),
    }

    // -- a corrupted push is refused with a typed checksum_mismatch ------
    let mut lying = wire::encode_push_model("corrupt", "v1", &artifact_bytes, false);
    *lying.last_mut().unwrap() ^= 0xff; // bit-rot after the checksum was computed
    let mut corrupt = TcpStream::connect(&addr).unwrap();
    corrupt.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    wire::write_frame(&mut corrupt, FrameKind::PushModel, &lying).unwrap();
    let frame = wire::read_frame(&mut corrupt, 1 << 20).unwrap();
    assert_eq!(frame.kind, FrameKind::Reply);
    match wire::decode_control_reply(&frame.payload).unwrap() {
        ControlOutcome::Rejected { reason, .. } => assert_eq!(reason, "checksum_mismatch"),
        other => panic!("expected checksum_mismatch, got {other:?}"),
    }
    drop(corrupt);

    // -- garbage with an honest checksum is refused as bad_artifact and
    //    never becomes routable or activatable ---------------------------
    match deployer
        .push_model("g", "v1", b"definitely not a model artifact", false)
        .unwrap()
    {
        ControlOutcome::Rejected { reason, .. } => assert_eq!(reason, "bad_artifact"),
        other => panic!("expected bad_artifact, got {other:?}"),
    }
    match deployer.activate_model("g", "v1").unwrap() {
        ControlOutcome::Rejected { reason, .. } => assert_eq!(reason, "unknown_model"),
        other => panic!("expected unknown_model, got {other:?}"),
    }
    match deployer
        .request("acme/g", &ServeRequest::row(ds.x.row(0).to_vec()))
        .unwrap()
    {
        WireOutcome::Rejected { reason, .. } => assert_eq!(reason, "unknown_route"),
        other => panic!("a refused artifact must never be routable, got {other:?}"),
    }
    drop(deployer);

    // -- raw garbage gets a typed malformed error, then a close ----------
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    raw.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
    let frame = wire::read_frame(&mut raw, 1 << 16).unwrap();
    assert_eq!(frame.kind, FrameKind::Error);
    assert_eq!(wire::decode_wire_error(&frame.payload).0, "malformed");
    let mut rest = Vec::new();
    raw.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server must close after a malformed header");
    drop(raw);

    // -- oversized is rejected from the header alone: a hand-crafted
    //    frame declaring u32::MAX payload bytes must be refused without
    //    the server allocating (or reading) any of them
    let mut big = TcpStream::connect(&addr).unwrap();
    big.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut lying_header = [0u8; 12];
    lying_header[..4].copy_from_slice(b"AVIW");
    lying_header[4] = wire::WIRE_VERSION;
    lying_header[5] = FrameKind::Request as u8;
    lying_header[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    big.write_all(&lying_header).unwrap();
    let frame = wire::read_frame(&mut big, 1 << 16).unwrap();
    assert_eq!(frame.kind, FrameKind::Error);
    assert_eq!(wire::decode_wire_error(&frame.payload).0, "oversized");
    drop(big);

    // -- a silent peer is reaped by the read deadline, not waited on -----
    let mut silent = TcpStream::connect(&addr).unwrap();
    silent.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    silent.read_to_end(&mut buf).unwrap(); // returns when the server reaps us
    assert!(buf.is_empty());
    drop(silent);

    // -- graceful shutdown drains the in-flight batch --------------------
    let drain_rows: Vec<Vec<f64>> = (8..24).map(|i| ds.x.row(i).to_vec()).collect();
    let mut a = WireClient::connect(&addr).unwrap();
    // warm-up proves conn A's handler is live before the shutdown races it
    assert!(a.request("acme/aux", &ServeRequest::row(ds.x.row(0).to_vec())).unwrap().answer().is_ok());
    let n_drain = drain_rows.len();
    let in_flight = std::thread::spawn(move || {
        a.request("acme/aux", &ServeRequest::batch(drain_rows)).unwrap().answer()
    });
    std::thread::sleep(Duration::from_millis(10));
    let b = WireClient::connect(&addr).unwrap();
    b.shutdown_server().unwrap();
    let drained = in_flight.join().unwrap().expect("in-flight batch must drain");
    assert_eq!(drained.predictions.len(), n_drain);

    // -- the process exits and reports every wire counter ----------------
    let mut tail = String::new();
    stdout.read_to_string(&mut tail).unwrap();
    let status = child.0.wait().unwrap();
    assert!(status.success(), "server exit: {status:?}\n{tail}");
    assert!(tail.contains("\"wire\""), "report must embed wire stats:\n{tail}");
    // happy batch + NaN + deadline (route m) + m2 batch + warm-up + drain
    assert_eq!(json_counter(&tail, "accepted"), 6, "{tail}");
    // two data-plane refusals on route m + one control-plane (m2 bucket)
    assert_eq!(json_counter(&tail, "rejected_limit"), 3, "{tail}");
    // bare-key 404 + the never-registered acme/g probe
    assert_eq!(json_counter(&tail, "rejected_route"), 2, "{tail}");
    // refused pushes (corrupt, garbage) and the rate-limited pull must
    // not count as model ops
    assert_eq!(json_counter(&tail, "model_pushes"), 1, "{tail}");
    assert_eq!(json_counter(&tail, "model_pulls"), 1, "{tail}");
    assert_eq!(json_counter(&tail, "model_activations"), 1, "{tail}");
    assert_eq!(json_counter(&tail, "oversized"), 1, "{tail}");
    assert!(json_counter(&tail, "malformed") >= 1, "{tail}");
    assert!(json_counter(&tail, "timed_out") >= 1, "{tail}");
    assert!(json_counter(&tail, "bytes_in") > 0 && json_counter(&tail, "bytes_out") > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

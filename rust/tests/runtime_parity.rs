//! Backend parity — the end-to-end checks of the data-plane contract:
//!
//! * **native ↔ sharded**: [`ShardedBackend`] must match
//!   [`NativeBackend`] **bit-for-bit** for any fixed store shard count
//!   (same per-shard kernels, same in-order reduction), across uneven m
//!   (including m < shards) and through a full OAVI fit.  These tests
//!   need no artifacts and always run.
//! * **native ↔ PJRT**: the JAX/Pallas-authored, AOT-compiled artifacts
//!   must compute the same numbers as the native Rust reference (within
//!   f32 tolerance), through the exact code path the production system
//!   uses.  Skips gracefully (with a loud message) if `make artifacts`
//!   has not run.

use std::path::Path;
use std::sync::Arc;

use avi_scale::backend::{
    ColumnStore, ComputeBackend, NativeBackend, PinnedShards, ShardedBackend,
};
use avi_scale::baselines::abm::{Abm, AbmConfig, AbmModel};
use avi_scale::baselines::vca::{Vca, VcaConfig};
use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::linalg::dense::Matrix;
use avi_scale::oavi::{Oavi, OaviConfig, OaviModel};
use avi_scale::util::proptest::property;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::gridsearch::{grid_search_two_level, GridParallelism};
use avi_scale::pipeline::{train_pipeline, train_pipeline_pooled, PipelineConfig};
use avi_scale::runtime::{PjrtRuntime, XlaBackend};
use avi_scale::svm::linear::LinearSvmConfig;
use avi_scale::util::rng::Rng;

// `backend::PinnedShards` pins the store shard count so two *execution
// strategies* (sequential native vs pool-sharded) are compared on
// byte-identical store layouts — the precondition of the bit-for-bit
// contract.

fn runtime() -> Option<Arc<PjrtRuntime>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match PjrtRuntime::load(&dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP runtime_parity: {e} (run `make artifacts`)");
            None
        }
    }
}

fn random_cols(rng: &mut Rng, m: usize, ell: usize) -> Vec<Vec<f64>> {
    (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect()
}

// ---------------------------------------------------------------------
// native ↔ sharded (no artifacts required)
// ---------------------------------------------------------------------

#[test]
fn sharded_gram_stats_bitwise_parity_suite() {
    // shard counts {1, 2, 3, 7} × uneven m including m < shards
    let mut rng = Rng::new(41);
    let sharded = ShardedBackend::new(4);
    for &shards in &[1usize, 2, 3, 7] {
        for &m in &[1usize, 2, 3, 5, 6, 7, 8, 41, 100, 1037] {
            let ell = 1 + (m % 5);
            let cols = random_cols(&mut rng, m, ell);
            let b: Vec<f64> = (0..m).map(|_| rng.uniform() - 0.4).collect();
            let store = ColumnStore::from_cols(&cols, shards);
            let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
            let (atb_s, btb_s) = sharded.gram_stats(&store, &b);
            assert_eq!(
                btb_n.to_bits(),
                btb_s.to_bits(),
                "btb bits diverge at m={m} shards={shards}"
            );
            for (j, (a, s)) in atb_n.iter().zip(atb_s.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    s.to_bits(),
                    "atb[{j}] bits diverge at m={m} shards={shards}: {a} vs {s}"
                );
            }
        }
    }
}

#[test]
fn sharded_transform_parity_suite() {
    let mut rng = Rng::new(43);
    let sharded = ShardedBackend::new(3);
    for &shards in &[1usize, 2, 3, 7] {
        for &m in &[1usize, 3, 5, 7, 64, 501] {
            let (ell, g) = (1 + (m % 4), 1 + (m % 3));
            let cols = random_cols(&mut rng, m, ell);
            let store = ColumnStore::from_cols(&cols, shards);
            let mut c = Matrix::zeros(ell, g);
            let mut u = Matrix::zeros(m, g);
            for j in 0..ell {
                for k in 0..g {
                    c.set(j, k, rng.normal());
                }
            }
            for i in 0..m {
                for k in 0..g {
                    u.set(i, k, rng.normal());
                }
            }
            let tn = NativeBackend.transform_abs(&store, &c, &u);
            let ts = sharded.transform_abs(&store, &c, &u);
            for (a, b) in tn.data().iter().zip(ts.data().iter()) {
                assert!(
                    (a - b).abs() <= 1e-12,
                    "transform diverges at m={m} shards={shards}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn sharded_parallel_path_bitwise_parity_at_scale() {
    // large enough per-shard work to clear the sequential-fallback gate,
    // so this exercises the actual pool fan-out + in-order reduction
    let mut rng = Rng::new(47);
    let sharded = ShardedBackend::new(4);
    let (m, ell, g) = (200_000usize, 8usize, 4usize);
    let cols = random_cols(&mut rng, m, ell);
    let b: Vec<f64> = (0..m).map(|_| rng.uniform() - 0.4).collect();
    let store = ColumnStore::from_cols(&cols, 4);
    let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
    let (atb_s, btb_s) = sharded.gram_stats(&store, &b);
    assert_eq!(btb_n.to_bits(), btb_s.to_bits());
    for (a, s) in atb_n.iter().zip(atb_s.iter()) {
        assert_eq!(a.to_bits(), s.to_bits());
    }
    let mut c = Matrix::zeros(ell, g);
    let mut u = Matrix::zeros(m, g);
    for j in 0..ell {
        for k in 0..g {
            c.set(j, k, rng.normal());
        }
    }
    for i in 0..m {
        for k in 0..g {
            u.set(i, k, rng.normal());
        }
    }
    let tn = NativeBackend.transform_abs(&store, &c, &u);
    let ts = sharded.transform_abs(&store, &c, &u);
    for (a, b) in tn.data().iter().zip(ts.data().iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "parallel transform diverges: {a} vs {b}");
    }
}

#[test]
fn oavi_fit_through_sharded_backend_matches_native() {
    // full fit: large enough m that preferred_shards > 1 actually shards
    let ds = synthetic_dataset(20_000, 7);
    let x = ds.class_matrix(0);
    let cfg = OaviConfig::cgavi_ihb(0.005);
    let sharded = ShardedBackend::new(4);
    assert!(
        sharded.preferred_shards(x.rows()) > 1,
        "test must exercise the multi-shard path (m = {})",
        x.rows()
    );
    let native_model = Oavi::new(cfg).fit(&x).unwrap();
    let sharded_model = Oavi::new(cfg).fit_with_backend(&x, &sharded).unwrap();
    assert_eq!(native_model.o_terms.len(), sharded_model.o_terms.len());
    assert_eq!(native_model.generators.len(), sharded_model.generators.len());
    for (a, b) in native_model.generators.iter().zip(sharded_model.generators.iter()) {
        assert_eq!(a.leading, b.leading);
        // shard-order summation differs from single-pass dots only at
        // the f64 rounding level
        assert!((a.mse - b.mse).abs() < 1e-9, "mse {} vs {}", a.mse, b.mse);
        for (ca, cb) in a.coeffs.iter().zip(b.coeffs.iter()) {
            assert!((ca - cb).abs() < 1e-7, "coeff {ca} vs {cb}");
        }
    }
}

#[test]
fn abm_fit_bitwise_parity_native_vs_sharded_per_shard_count() {
    // the baselines satellite: for a FIXED store shard count, a full ABM
    // fit through ShardedBackend must match NativeBackend bit for bit
    // (same per-shard kernels, same in-order reduction)
    let ds = synthetic_dataset(4000, 17);
    let x = ds.class_matrix(0);
    for shards in [1usize, 3, 4] {
        let native_pin = PinnedShards::new(Box::new(NativeBackend), shards);
        let sharded_pin = PinnedShards::new(Box::new(ShardedBackend::new(4)), shards);
        let a = Abm::new(AbmConfig::new(0.01)).fit_with_backend(&x, &native_pin).unwrap();
        let b = Abm::new(AbmConfig::new(0.01)).fit_with_backend(&x, &sharded_pin).unwrap();
        assert_eq!(a.o_terms.len(), b.o_terms.len(), "|O| diverges at shards={shards}");
        assert_eq!(a.generators.len(), b.generators.len());
        for (ga, gb) in a.generators.iter().zip(b.generators.iter()) {
            assert_eq!(ga.leading, gb.leading);
            assert_eq!(ga.mse.to_bits(), gb.mse.to_bits(), "mse bits at shards={shards}");
            for (ca, cb) in ga.coeffs.iter().zip(gb.coeffs.iter()) {
                assert_eq!(ca.to_bits(), cb.to_bits(), "coeff bits at shards={shards}");
            }
        }
        // the (FT) transform must also agree bitwise
        let ta = a.generator_set().transform_with(&x, &native_pin);
        let tb = b.generator_set().transform_with(&x, &sharded_pin);
        for (va, vb) in ta.data().iter().zip(tb.data().iter()) {
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }
}

#[test]
fn vca_fit_bitwise_parity_native_vs_sharded_per_shard_count() {
    // same contract for VCA now that its projections + candidate Gram go
    // through ComputeBackend::gram_stats
    let ds = synthetic_dataset(3000, 19);
    let x = ds.class_matrix(1);
    for shards in [1usize, 2, 4] {
        let native_pin = PinnedShards::new(Box::new(NativeBackend), shards);
        let sharded_pin = PinnedShards::new(Box::new(ShardedBackend::new(3)), shards);
        let a = Vca::new(VcaConfig::new(0.005)).fit_with_backend(&x, &native_pin).unwrap();
        let b = Vca::new(VcaConfig::new(0.005)).fit_with_backend(&x, &sharded_pin).unwrap();
        assert_eq!(a.n_generators(), b.n_generators(), "|V| diverges at shards={shards}");
        assert_eq!(a.total_size(), b.total_size());
        let ta = a.transform_with(&x, &native_pin);
        let tb = b.transform_with(&x, &sharded_pin);
        assert_eq!(ta.cols(), tb.cols());
        for (va, vb) in ta.data().iter().zip(tb.data().iter()) {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "VCA transform bits diverge at shards={shards}: {va} vs {vb}"
            );
        }
    }
}

#[test]
fn oavi_fit_bitwise_parity_native_vs_sharded_per_shard_count() {
    // the same pinned-shards contract through the OAVI driver (the
    // approximate cross-shard-count check below predates this one)
    let ds = synthetic_dataset(2500, 23);
    let x = ds.class_matrix(0);
    for shards in [2usize, 5] {
        let native_pin = PinnedShards::new(Box::new(NativeBackend), shards);
        let sharded_pin = PinnedShards::new(Box::new(ShardedBackend::new(4)), shards);
        let cfg = OaviConfig::cgavi_ihb(0.005);
        let a = Oavi::new(cfg).fit_with_backend(&x, &native_pin).unwrap();
        let b = Oavi::new(cfg).fit_with_backend(&x, &sharded_pin).unwrap();
        assert_eq!(a.o_terms.len(), b.o_terms.len());
        assert_eq!(a.generators.len(), b.generators.len());
        for (ga, gb) in a.generators.iter().zip(b.generators.iter()) {
            assert_eq!(ga.mse.to_bits(), gb.mse.to_bits());
            for (ca, cb) in ga.coeffs.iter().zip(gb.coeffs.iter()) {
                assert_eq!(ca.to_bits(), cb.to_bits());
            }
        }
    }
}

#[test]
fn two_level_grid_search_bitwise_equals_all_native() {
    // ISSUE 3 satellite: sharded grid search (outer jobs) each fitting
    // through a sharded backend (inner shards) must be bitwise equal to
    // the all-native run for the pinned (outer, inner, shards) triple —
    // here (3 pool workers, inner budget 2, 4 store shards).
    let ds = synthetic_dataset(1200, 31);
    let est = [EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))];
    let psis = [0.05, 0.01];
    let lambdas = [1e-3];

    let pool_par = ThreadPool::new(3);
    let two_level = grid_search_two_level(
        &est,
        FeatureOrdering::Pearson,
        &ds,
        &psis,
        &lambdas,
        3,
        7,
        &pool_par,
        GridParallelism { intra_workers: 2, pin_store_shards: Some(4) },
    )
    .unwrap();

    let pool_seq = ThreadPool::new(1);
    let all_native = grid_search_two_level(
        &est,
        FeatureOrdering::Pearson,
        &ds,
        &psis,
        &lambdas,
        3,
        7,
        &pool_seq,
        GridParallelism { intra_workers: 1, pin_store_shards: Some(4) },
    )
    .unwrap();

    assert_eq!(two_level.table.len(), all_native.table.len());
    for (a, b) in two_level.table.iter().zip(all_native.table.iter()) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.psi.to_bits(), b.psi.to_bits());
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
        assert_eq!(
            a.cv_error.to_bits(),
            b.cv_error.to_bits(),
            "cv error bits diverge at psi={} lambda={}",
            a.psi,
            a.lambda
        );
    }
    assert_eq!(two_level.best_psi.to_bits(), all_native.best_psi.to_bits());
    assert_eq!(two_level.best_lambda.to_bits(), all_native.best_lambda.to_bits());
    assert_eq!(two_level.best_cv_error.to_bits(), all_native.best_cv_error.to_bits());
    assert_eq!(two_level.best_name, all_native.best_name);
}

#[test]
fn pooled_per_class_pipeline_bitwise_matches_native_on_single_shard_stores() {
    // per-class fits as outer pool jobs: with m below the shard floor
    // every store is single-shard, so the pooled two-level pipeline must
    // reproduce the sequential native pipeline exactly
    let ds = synthetic_dataset(800, 29);
    let cfg = PipelineConfig {
        estimator: EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01)),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    let seq = train_pipeline(&cfg, &ds).unwrap();
    let pool = ThreadPool::new(4);
    let par = train_pipeline_pooled(&cfg, &ds, &pool).unwrap();
    assert_eq!(seq.perm, par.perm);
    assert_eq!(seq.transformer.n_generators(), par.transformer.n_generators());
    let probe = synthetic_dataset(120, 30);
    let fa = seq.transformer.transform(&probe.x);
    let fb = par.transformer.transform(&probe.x);
    for (a, b) in fa.data().iter().zip(fb.data().iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "pooled (FT) features diverge");
    }
    assert_eq!(seq.predict(&probe.x), par.predict(&probe.x));
}

// ---------------------------------------------------------------------
// degree-batched panels ↔ legacy per-candidate (ISSUE 5)
// ---------------------------------------------------------------------

/// Bitwise model equality: generators (leading term, coeff bits, mse
/// bits), O terms, and the final maintained inverse-Gram `(B, N)`.
fn assert_oavi_models_bitwise(a: &OaviModel, b: &OaviModel, ctx: &str) -> Result<(), String> {
    if a.o_terms.len() != b.o_terms.len() {
        return Err(format!("{ctx}: |O| {} vs {}", a.o_terms.len(), b.o_terms.len()));
    }
    if a.o_terms.terms() != b.o_terms.terms() {
        return Err(format!("{ctx}: O terms diverge"));
    }
    if a.generators.len() != b.generators.len() {
        return Err(format!("{ctx}: |G| {} vs {}", a.generators.len(), b.generators.len()));
    }
    for (gi, (ga, gb)) in a.generators.iter().zip(b.generators.iter()).enumerate() {
        if ga.leading != gb.leading {
            return Err(format!("{ctx}: generator {gi} leading term diverges"));
        }
        if ga.mse.to_bits() != gb.mse.to_bits() {
            return Err(format!("{ctx}: generator {gi} mse bits diverge"));
        }
        if ga.coeffs.len() != gb.coeffs.len() {
            return Err(format!("{ctx}: generator {gi} coeff arity diverges"));
        }
        for (j, (ca, cb)) in ga.coeffs.iter().zip(gb.coeffs.iter()).enumerate() {
            if ca.to_bits() != cb.to_bits() {
                return Err(format!("{ctx}: generator {gi} coeff {j}: {ca} vs {cb}"));
            }
        }
    }
    for (name, ma, mb) in [
        ("B", a.final_gram.b(), b.final_gram.b()),
        ("N", a.final_gram.n_inv(), b.final_gram.n_inv()),
    ] {
        if ma.rows() != mb.rows() {
            return Err(format!("{ctx}: {name} shape diverges"));
        }
        for (va, vb) in ma.data().iter().zip(mb.data().iter()) {
            if va.to_bits() != vb.to_bits() {
                return Err(format!("{ctx}: {name} bits diverge ({va} vs {vb})"));
            }
        }
    }
    Ok(())
}

fn assert_abm_models_bitwise(a: &AbmModel, b: &AbmModel, ctx: &str) -> Result<(), String> {
    if a.o_terms.len() != b.o_terms.len() || a.o_terms.terms() != b.o_terms.terms() {
        return Err(format!("{ctx}: O diverges"));
    }
    if a.generators.len() != b.generators.len() {
        return Err(format!("{ctx}: |G| diverges"));
    }
    for (gi, (ga, gb)) in a.generators.iter().zip(b.generators.iter()).enumerate() {
        if ga.leading != gb.leading || ga.mse.to_bits() != gb.mse.to_bits() {
            return Err(format!("{ctx}: generator {gi} diverges"));
        }
        for (ca, cb) in ga.coeffs.iter().zip(gb.coeffs.iter()) {
            if ca.to_bits() != cb.to_bits() {
                return Err(format!("{ctx}: generator {gi} coeff bits diverge"));
            }
        }
    }
    Ok(())
}

#[test]
fn oavi_panel_path_bitwise_equals_per_candidate_path() {
    // the ISSUE 5 tentpole contract: random data × random ψ × IHB/WIHB,
    // legacy per-candidate flow vs degree-batched panel flow, native AND
    // pool-sharded execution on pinned store layouts — generators, O
    // terms, and the maintained inverse Gram must agree bit for bit
    property(5, |rng| {
        let m = 120 + rng.below(180);
        let n = 2 + rng.below(2);
        let mut x = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                x.set(i, j, rng.uniform());
            }
        }
        let psi = [0.05, 0.01, 0.002][rng.below(3)];
        for shards in [1usize, 3] {
            for cfg in [OaviConfig::cgavi_ihb(psi), OaviConfig::bpcgavi_wihb(psi)] {
                let native_pin = PinnedShards::new(Box::new(NativeBackend), shards);
                // min_work 0 forces the pool fan-out even at these sizes
                let sharded_pin = PinnedShards::new(
                    Box::new(ShardedBackend::new(3).with_min_work(0)),
                    shards,
                );
                let legacy = Oavi::new(cfg)
                    .fit_with_backend_per_candidate(&x, &native_pin)
                    .map_err(|e| e.to_string())?;
                let panel_native =
                    Oavi::new(cfg).fit_with_backend(&x, &native_pin).map_err(|e| e.to_string())?;
                let panel_sharded = Oavi::new(cfg)
                    .fit_with_backend(&x, &sharded_pin)
                    .map_err(|e| e.to_string())?;
                let ctx = format!("{} psi={psi} shards={shards}", cfg.name());
                assert_oavi_models_bitwise(&legacy, &panel_native, &format!("{ctx} native"))?;
                assert_oavi_models_bitwise(&legacy, &panel_sharded, &format!("{ctx} sharded"))?;
                if panel_native.stats.panel_cols != panel_native.stats.oracle_calls {
                    return Err(format!("{ctx}: panel_cols != oracle_calls"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn oavi_chunked_panel_bitwise_equals_per_candidate() {
    // panel_budget_cols below the border width forces multi-chunk
    // degrees; chunking must stay invisible in the bits
    let ds = synthetic_dataset(900, 37);
    let x = ds.class_matrix(0);
    let mut chunked = OaviConfig::cgavi_ihb(0.01);
    chunked.panel_budget_cols = 2;
    let legacy = Oavi::new(OaviConfig::cgavi_ihb(0.01))
        .fit_with_backend_per_candidate(&x, &NativeBackend)
        .unwrap();
    for shards in [1usize, 4] {
        let native_pin = PinnedShards::new(Box::new(NativeBackend), shards);
        let sharded_pin =
            PinnedShards::new(Box::new(ShardedBackend::new(4).with_min_work(0)), shards);
        let a = Oavi::new(chunked).fit_with_backend(&x, &native_pin).unwrap();
        let b = Oavi::new(chunked).fit_with_backend(&x, &sharded_pin).unwrap();
        assert_oavi_models_bitwise(&legacy, &a, &format!("chunked native shards={shards}"))
            .unwrap();
        assert_oavi_models_bitwise(&legacy, &b, &format!("chunked sharded shards={shards}"))
            .unwrap();
        // the degree-1 border alone is 3 wide (n = 3 features), so a
        // 2-column budget must have split at least one degree
        assert!(
            a.stats.panel_passes > a.stats.degree_reached as usize,
            "budget 2 must force multi-chunk degrees ({} passes, degree {})",
            a.stats.panel_passes,
            a.stats.degree_reached
        );
    }
}

#[test]
fn abm_panel_path_bitwise_equals_per_candidate_path() {
    let ds = synthetic_dataset(2000, 17);
    let x = ds.class_matrix(0);
    for shards in [1usize, 3] {
        let native_pin = PinnedShards::new(Box::new(NativeBackend), shards);
        let sharded_pin =
            PinnedShards::new(Box::new(ShardedBackend::new(3).with_min_work(0)), shards);
        let legacy = Abm::new(AbmConfig::new(0.01))
            .fit_with_backend_per_candidate(&x, &native_pin)
            .unwrap();
        let a = Abm::new(AbmConfig::new(0.01)).fit_with_backend(&x, &native_pin).unwrap();
        let b = Abm::new(AbmConfig::new(0.01)).fit_with_backend(&x, &sharded_pin).unwrap();
        assert_abm_models_bitwise(&legacy, &a, &format!("abm native shards={shards}")).unwrap();
        assert_abm_models_bitwise(&legacy, &b, &format!("abm sharded shards={shards}")).unwrap();
        assert!(a.stats.panel_passes > 0);
        assert_eq!(legacy.stats.panel_passes, 0);
    }
}

#[test]
fn vca_panel_path_bitwise_equals_per_candidate_path() {
    let ds = synthetic_dataset(1500, 19);
    let x = ds.class_matrix(1);
    for shards in [1usize, 2] {
        let native_pin = PinnedShards::new(Box::new(NativeBackend), shards);
        let sharded_pin =
            PinnedShards::new(Box::new(ShardedBackend::new(3).with_min_work(0)), shards);
        let legacy = Vca::new(VcaConfig::new(0.005))
            .fit_with_backend_per_candidate(&x, &native_pin)
            .unwrap();
        for (label, backend) in
            [("native", &native_pin as &dyn ComputeBackend), ("sharded", &sharded_pin)]
        {
            let panel =
                Vca::new(VcaConfig::new(0.005)).fit_with_backend(&x, backend).unwrap();
            assert_eq!(legacy.n_generators(), panel.n_generators(), "{label} |V|");
            assert_eq!(legacy.total_size(), panel.total_size(), "{label} size");
            let ta = legacy.transform_with(&x, &native_pin);
            let tb = panel.transform_with(&x, backend);
            assert_eq!(ta.cols(), tb.cols());
            for (va, vb) in ta.data().iter().zip(tb.data().iter()) {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "VCA {label} transform bits diverge at shards={shards}"
                );
            }
            for (ma, mb) in legacy.mse_on(&x).iter().zip(panel.mse_on(&x).iter()) {
                assert_eq!(ma.to_bits(), mb.to_bits(), "{label} mse bits");
            }
            assert!(panel.stats.panel_passes > 0, "{label}");
        }
    }
}

#[test]
fn sharded_panel_fit_issues_one_dispatch_per_degree_chunk() {
    // the ISSUE 5 acceptance bar: ≤ 1 pool dispatch per (degree, panel
    // chunk) on the sharded backend — asserted exactly via the pool's
    // batch counter (the per-candidate flow would pay one per oracle call)
    let ds = synthetic_dataset(2400, 41);
    let x = ds.class_matrix(0);
    let pool = ThreadPool::new(4);
    let backend = ShardedBackend::with_handle(pool.handle(), 4, 64).with_min_work(0);
    let pinned = PinnedShards::new(Box::new(backend), 4);
    let before = pool.handle().batches_dispatched();
    let model = Oavi::new(OaviConfig::cgavi_ihb(0.01)).fit_with_backend(&x, &pinned).unwrap();
    let after = pool.handle().batches_dispatched();
    assert!(model.stats.panel_passes > 0);
    assert_eq!(
        after - before,
        model.stats.panel_passes as u64,
        "panel fit must dispatch exactly once per (degree, chunk)"
    );
    assert!(
        (after - before) < model.stats.oracle_calls as u64,
        "batching must beat one dispatch per oracle call ({} calls)",
        model.stats.oracle_calls
    );
}

// ---------------------------------------------------------------------
// native ↔ PJRT (skips without artifacts)
// ---------------------------------------------------------------------

#[test]
fn gram_stats_parity_small() {
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    let native = NativeBackend;
    let mut rng = Rng::new(1);
    for (m, ell) in [(100usize, 3usize), (4096, 10), (5000, 40), (9000, 64)] {
        let cols = random_cols(&mut rng, m, ell);
        let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let store = ColumnStore::from_cols(&cols, 1);
        let (atb_x, btb_x) = xla.gram_stats(&store, &b);
        let (atb_n, btb_n) = native.gram_stats(&store, &b);
        let scale = m as f64;
        for j in 0..ell {
            assert!(
                (atb_x[j] - atb_n[j]).abs() < 1e-3 * scale,
                "m={m} ell={ell} atb[{j}]: {} vs {}",
                atb_x[j],
                atb_n[j]
            );
        }
        assert!((btb_x - btb_n).abs() < 1e-3 * scale, "btb {} vs {}", btb_x, btb_n);
    }
}

#[test]
fn gram_stats_parity_sharded_store() {
    // PJRT tiles each shard independently; results must stay within f32
    // tolerance of native on the same multi-shard store
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    let mut rng = Rng::new(5);
    let (m, ell) = (5000usize, 12usize);
    let cols = random_cols(&mut rng, m, ell);
    let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
    for shards in [2usize, 3, 7] {
        let store = ColumnStore::from_cols(&cols, shards);
        let (atb_x, btb_x) = xla.gram_stats(&store, &b);
        let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
        let scale = m as f64;
        for j in 0..ell {
            assert!((atb_x[j] - atb_n[j]).abs() < 1e-3 * scale);
        }
        assert!((btb_x - btb_n).abs() < 1e-3 * scale);
    }
}

#[test]
fn transform_parity() {
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    let native = NativeBackend;
    let mut rng = Rng::new(2);
    let (m, ell, g) = (5000usize, 12usize, 7usize);
    let cols = random_cols(&mut rng, m, ell);
    let store = ColumnStore::from_cols(&cols, 1);
    let mut c = Matrix::zeros(ell, g);
    let mut u = Matrix::zeros(m, g);
    for j in 0..ell {
        for k in 0..g {
            c.set(j, k, rng.normal());
        }
    }
    for i in 0..m {
        for k in 0..g {
            u.set(i, k, rng.normal());
        }
    }
    let tx = xla.transform_abs(&store, &c, &u);
    let tn = native.transform_abs(&store, &c, &u);
    let mut worst = 0.0f64;
    for i in 0..m {
        for k in 0..g {
            worst = worst.max((tx.get(i, k) - tn.get(i, k)).abs());
        }
    }
    assert!(worst < 1e-3, "worst transform deviation {worst}");
}

#[test]
fn oavi_fit_through_xla_backend_matches_native() {
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    let ds = synthetic_dataset(2000, 7);
    let x = ds.class_matrix(0);
    let cfg = OaviConfig::cgavi_ihb(0.005);
    let native_model = Oavi::new(cfg).fit(&x).unwrap();
    let xla_model = Oavi::new(cfg).fit_with_backend(&x, &xla).unwrap();
    // identical structure discovery (f32 stats are well inside the ψ margin)
    assert_eq!(native_model.o_terms.len(), xla_model.o_terms.len());
    assert_eq!(native_model.generators.len(), xla_model.generators.len());
    for (a, b) in native_model.generators.iter().zip(xla_model.generators.iter()) {
        assert_eq!(a.leading, b.leading);
        assert!((a.mse - b.mse).abs() < 1e-4, "mse {} vs {}", a.mse, b.mse);
    }
}

#[test]
fn fallback_beyond_artifact_width() {
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    // ℓ = 300 exceeds the largest L_PAD=256 artifact ⇒ silent native fallback
    let mut rng = Rng::new(3);
    let m = 200;
    let cols = random_cols(&mut rng, m, 300);
    let store = ColumnStore::from_cols(&cols, 1);
    let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
    let (atb_x, btb_x) = xla.gram_stats(&store, &b);
    let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);
    assert_eq!(atb_x, atb_n); // exact: same f64 code path
    assert_eq!(btb_x, btb_n);
}

#[test]
fn runtime_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    assert!(rt.gram_artifact_for(1).is_some());
    assert!(rt.gram_artifact_for(64).is_some());
    assert!(rt.gram_artifact_for(200).is_some());
    assert!(rt.gram_artifact_for(257).is_none());
    assert!(rt.transform_artifact_for(10, 10).is_some());
    assert!(rt.transform_artifact_for(10, 500).is_none());
}

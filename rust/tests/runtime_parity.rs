//! PJRT runtime ↔ native backend parity — the end-to-end check of the
//! three-layer contract: the JAX/Pallas-authored, AOT-compiled artifacts
//! must compute the same numbers as the native Rust reference (within f32
//! tolerance), through the exact code path the production system uses.
//!
//! Skips gracefully (with a loud message) if `make artifacts` has not run.

use std::path::Path;
use std::sync::Arc;

use avi_scale::backend::{ComputeBackend, NativeBackend};
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::linalg::dense::Matrix;
use avi_scale::oavi::{Oavi, OaviConfig};
use avi_scale::runtime::{PjrtRuntime, XlaBackend};
use avi_scale::util::rng::Rng;

fn runtime() -> Option<Arc<PjrtRuntime>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match PjrtRuntime::load(&dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP runtime_parity: {e} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn gram_stats_parity_small() {
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    let native = NativeBackend;
    let mut rng = Rng::new(1);
    for (m, ell) in [(100usize, 3usize), (4096, 10), (5000, 40), (9000, 64)] {
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
        let (atb_x, btb_x) = xla.gram_stats(&cols, &b);
        let (atb_n, btb_n) = native.gram_stats(&cols, &b);
        let scale = m as f64;
        for j in 0..ell {
            assert!(
                (atb_x[j] - atb_n[j]).abs() < 1e-3 * scale,
                "m={m} ell={ell} atb[{j}]: {} vs {}",
                atb_x[j],
                atb_n[j]
            );
        }
        assert!((btb_x - btb_n).abs() < 1e-3 * scale, "btb {} vs {}", btb_x, btb_n);
    }
}

#[test]
fn transform_parity() {
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    let native = NativeBackend;
    let mut rng = Rng::new(2);
    let (m, ell, g) = (5000usize, 12usize, 7usize);
    let cols: Vec<Vec<f64>> =
        (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
    let mut c = Matrix::zeros(ell, g);
    let mut u = Matrix::zeros(m, g);
    for j in 0..ell {
        for k in 0..g {
            c.set(j, k, rng.normal());
        }
    }
    for i in 0..m {
        for k in 0..g {
            u.set(i, k, rng.normal());
        }
    }
    let tx = xla.transform_abs(&cols, &c, &u);
    let tn = native.transform_abs(&cols, &c, &u);
    let mut worst = 0.0f64;
    for i in 0..m {
        for k in 0..g {
            worst = worst.max((tx.get(i, k) - tn.get(i, k)).abs());
        }
    }
    assert!(worst < 1e-3, "worst transform deviation {worst}");
}

#[test]
fn oavi_fit_through_xla_backend_matches_native() {
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    let ds = synthetic_dataset(2000, 7);
    let x = ds.class_matrix(0);
    let cfg = OaviConfig::cgavi_ihb(0.005);
    let native_model = Oavi::new(cfg).fit(&x).unwrap();
    let xla_model = Oavi::new(cfg).fit_with_backend(&x, &xla).unwrap();
    // identical structure discovery (f32 stats are well inside the ψ margin)
    assert_eq!(native_model.o_terms.len(), xla_model.o_terms.len());
    assert_eq!(native_model.generators.len(), xla_model.generators.len());
    for (a, b) in native_model.generators.iter().zip(xla_model.generators.iter()) {
        assert_eq!(a.leading, b.leading);
        assert!((a.mse - b.mse).abs() < 1e-4, "mse {} vs {}", a.mse, b.mse);
    }
}

#[test]
fn fallback_beyond_artifact_width() {
    let Some(rt) = runtime() else { return };
    let xla = XlaBackend::new(rt);
    // ℓ = 300 exceeds the largest L_PAD=256 artifact ⇒ silent native fallback
    let mut rng = Rng::new(3);
    let m = 200;
    let cols: Vec<Vec<f64>> =
        (0..300).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
    let b: Vec<f64> = (0..m).map(|_| rng.uniform()).collect();
    let (atb_x, btb_x) = xla.gram_stats(&cols, &b);
    let (atb_n, btb_n) = NativeBackend.gram_stats(&cols, &b);
    assert_eq!(atb_x, atb_n); // exact: same f64 code path
    assert_eq!(btb_x, btb_n);
}

#[test]
fn runtime_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    assert!(rt.gram_artifact_for(1).is_some());
    assert!(rt.gram_artifact_for(64).is_some());
    assert!(rt.gram_artifact_for(200).is_some());
    assert!(rt.gram_artifact_for(257).is_none());
    assert!(rt.transform_artifact_for(10, 10).is_some());
    assert!(rt.transform_artifact_for(10, 500).is_none());
}

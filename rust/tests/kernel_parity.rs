//! Kernel parity — the ISSUE 6 contract suite for the row-tiled/
//! wide-lane panel micro-kernels and the opt-in mixed-precision path:
//!
//! * **dotN ↔ dot**: the generic wide-lane brick must be bitwise equal
//!   to [`avi_scale::linalg::dot`] per column for every lane width, for
//!   lengths crossing every 4-lane boundary.
//! * **tiled ↔ untiled**: [`gram_panel_partial_tiled`] must be bitwise
//!   equal to the per-entry `dot` reference for every 4-multiple tile
//!   size, shard counts that leave uneven/empty shards, and m that is
//!   not a multiple of the tile.
//! * **threshold paths**: the scalar and tiled kernel paths selected by
//!   the `set_block_threshold_bytes` override hook must agree bitwise
//!   through the public `gram_panel` entry point, native and sharded.
//! * **lazy ↔ eager cross**: rows materialized on demand must carry the
//!   same bits as the eager triangle, through the forced-parallel
//!   sharded backend.
//! * **fast budget**: the opt-in f32 path's reported error budget must
//!   bound the true max deviation from the f64 reference, at the kernel
//!   level and through a full fit.
//!
//! These tests intentionally run under both serial and default test
//! threading in `scripts/verify.sh` — the sharded reduction and the
//! process-global threshold hook must be order-independent.

use std::sync::Mutex;

use avi_scale::backend::store::{
    gram_panel_fast_seq, gram_panel_partial, gram_panel_partial_tiled, gram_panel_seq,
    set_block_threshold_bytes, BLOCK_THRESHOLD_DEFAULT,
};
use avi_scale::backend::{
    CandidatePanel, ColumnStore, ComputeBackend, CrossMode, NativeBackend, NumericsMode,
    ShardedBackend,
};
use avi_scale::linalg::{dot, simd};
use avi_scale::oavi::{Oavi, OaviConfig};
use avi_scale::util::proptest::property;
use avi_scale::util::rng::Rng;

/// Serializes tests that pin the process-global block threshold.  Every
/// path the threshold selects between is bitwise identical, so races
/// would not corrupt results — but pinning must be observable within a
/// test for it to actually exercise the intended kernel.
static THRESHOLD_LOCK: Mutex<()> = Mutex::new(());

fn random_cols(rng: &mut Rng, m: usize, ell: usize) -> Vec<Vec<f64>> {
    (0..ell).map(|_| (0..m).map(|_| rng.uniform() - 0.3).collect()).collect()
}

fn build_panel(store: &ColumnStore, rng: &mut Rng, k: usize) -> CandidatePanel {
    let mut panel = CandidatePanel::new_like(store);
    let m = store.rows();
    for _ in 0..k {
        let c: Vec<f64> = (0..m).map(|_| rng.uniform() - 0.5).collect();
        panel.push_col(&c);
    }
    panel
}

// ---------------------------------------------------------------------
// dotN ↔ dot
// ---------------------------------------------------------------------

#[test]
fn dotn_is_bitwise_dot_for_all_lane_widths_and_boundary_lengths() {
    property(60, |rng| {
        // lengths straddling every n % 4 residue and the empty case
        let n = (rng.uniform() * 70.0) as usize;
        let cols: Vec<Vec<f64>> =
            (0..8).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        let c2: [&[f64]; 2] = [&cols[0], &cols[1]];
        let c4: [&[f64]; 4] = std::array::from_fn(|i| cols[i].as_slice());
        let c8: [&[f64]; 8] = std::array::from_fn(|i| cols[i].as_slice());
        let r2 = simd::dotn(&c2, &b);
        let r4 = simd::dotn(&c4, &b);
        let r8 = simd::dotn(&c8, &b);
        for (w, got) in
            r2.iter().chain(r4.iter()).chain(r8.iter()).enumerate()
        {
            let col = &cols[if w < 2 { w } else if w < 6 { w - 2 } else { w - 6 }];
            let want = dot(col, &b);
            if got.to_bits() != want.to_bits() {
                return Err(format!("dotn diverged from dot at n={n} slot={w}"));
            }
        }
        Ok(())
    });
}

#[test]
fn carried_lanes_across_arbitrary_tile_splits_match_single_pass_dot() {
    property(60, |rng| {
        let n = 8 + (rng.uniform() * 120.0) as usize;
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let full = n & !3usize;
        // random 4-multiple split points over the lane region
        let mut lanes = [0.0f64; 4];
        let mut t0 = 0usize;
        while t0 < full {
            let step = 4 * (1 + (rng.uniform() * 6.0) as usize);
            let t1 = (t0 + step).min(full);
            simd::lanes_update(&mut lanes, &a[t0..t1], &b[t0..t1]);
            t0 = t1;
        }
        let got = simd::lanes_finish(lanes, &a[full..], &b[full..]);
        let want = dot(&a, &b);
        if got.to_bits() != want.to_bits() {
            return Err(format!("carried lanes diverged at n={n}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// tiled ↔ untiled panel kernel
// ---------------------------------------------------------------------

#[test]
fn tiled_panel_partial_is_bitwise_dot_for_all_tile_sizes_and_shards() {
    property(40, |rng| {
        let m = 1 + (rng.uniform() * 90.0) as usize; // deliberately not tile-aligned
        let ell = 1 + (rng.uniform() * 11.0) as usize;
        let k = 1 + (rng.uniform() * 19.0) as usize;
        let shards = 1 + (rng.uniform() * 4.0) as usize; // may exceed m → empty shards
        let cols = random_cols(rng, m, ell);
        let store = ColumnStore::from_cols(&cols, shards);
        let panel = build_panel(&store, rng, k);
        for s in 0..store.n_shards() {
            let untiled = gram_panel_partial(&store, &panel, s, 0..k);
            for &tile_rows in &[4usize, 8, 12, 64, 1024] {
                let tiled = gram_panel_partial_tiled(&store, &panel, s, 0..k, tile_rows);
                for c in 0..k {
                    for j in 0..ell {
                        let want = dot(store.col_shard(j, s), panel.col_shard(c, s));
                        let got = tiled[c * ell + j];
                        if got.to_bits() != want.to_bits() {
                            return Err(format!(
                                "tiled != dot at m={m} shards={shards} s={s} tile={tile_rows} c={c} j={j}"
                            ));
                        }
                        if got.to_bits() != untiled[c * ell + j].to_bits() {
                            return Err(format!(
                                "tiled != untiled at m={m} s={s} tile={tile_rows} c={c} j={j}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn threshold_override_selects_bitwise_identical_paths_end_to_end() {
    let _guard = THRESHOLD_LOCK.lock().unwrap();
    let mut rng = Rng::new(97);
    let (m, ell, k) = (2053usize, 9usize, 13usize);
    let cols = random_cols(&mut rng, m, ell);
    for &shards in &[1usize, 3] {
        let store = ColumnStore::from_cols(&cols, shards);
        let panel = build_panel(&store, &mut rng, k);
        let sharded = ShardedBackend::new(4).with_min_work(0);

        set_block_threshold_bytes(usize::MAX); // pin the scalar per-column kernel
        let scalar = gram_panel_seq(&store, &panel, CrossMode::Eager);
        let scalar_sh = sharded.gram_panel(&store, &panel, CrossMode::Eager, NumericsMode::Exact);
        set_block_threshold_bytes(1); // pin the row-tiled wide-lane kernel
        let tiled = gram_panel_seq(&store, &panel, CrossMode::Eager);
        let tiled_sh = sharded.gram_panel(&store, &panel, CrossMode::Eager, NumericsMode::Exact);
        set_block_threshold_bytes(BLOCK_THRESHOLD_DEFAULT);

        for ps in [&scalar_sh, &tiled, &tiled_sh] {
            for c in 0..k {
                for (a, b) in scalar.atb_col(c).iter().zip(ps.atb_col(c).iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "atb path divergence at shards={shards}");
                }
                for i in 0..=c {
                    assert_eq!(
                        scalar.cross_at(i, c).to_bits(),
                        ps.cross_at(i, c).to_bits(),
                        "cross path divergence at shards={shards}"
                    );
                }
            }
        }
        // and both pinned paths must reproduce the per-entry reference
        let mut acc = dot(store.col_shard(0, 0), panel.col_shard(0, 0));
        for s in 1..store.n_shards() {
            acc += dot(store.col_shard(0, s), panel.col_shard(0, s));
        }
        assert_eq!(acc.to_bits(), scalar.atb_col(0)[0].to_bits());
    }
}

// ---------------------------------------------------------------------
// lazy ↔ eager cross rows
// ---------------------------------------------------------------------

#[test]
fn lazy_cross_rows_match_eager_triangle_through_forced_parallel_backend() {
    property(25, |rng| {
        let m = 5 + (rng.uniform() * 400.0) as usize;
        let ell = 1 + (rng.uniform() * 7.0) as usize;
        let k = 2 + (rng.uniform() * 10.0) as usize;
        let shards = 1 + (rng.uniform() * 3.0) as usize;
        let cols = random_cols(rng, m, ell);
        let store = ColumnStore::from_cols(&cols, shards);
        let panel = build_panel(&store, rng, k);
        let sharded = ShardedBackend::new(3).with_min_work(0);

        let eager = sharded.gram_panel(&store, &panel, CrossMode::Eager, NumericsMode::Exact);
        let mut lazy = sharded.gram_panel(&store, &panel, CrossMode::Lazy, NumericsMode::Exact);
        if !lazy.is_lazy() {
            return Err("Lazy mode did not produce a lazy PanelStats".into());
        }
        for c in 0..k {
            if eager.btb(c).to_bits() != lazy.btb(c).to_bits() {
                return Err(format!("lazy diag diverged at c={c}"));
            }
        }
        for i in 0..k {
            lazy.ensure_cross_row(&panel, i);
            for c in i..k {
                if eager.cross_at(i, c).to_bits() != lazy.cross_at(i, c).to_bits() {
                    return Err(format!("lazy row diverged at ({i},{c})"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// fast-mode error budget
// ---------------------------------------------------------------------

#[test]
fn fast_kernel_budget_bounds_true_deviation_on_conditioned_gram() {
    let mut rng = Rng::new(131);
    let (m, ell, k) = (20_000usize, 6usize, 9usize);
    // well-conditioned data: uniform in [0, 1), no cancellation
    let cols: Vec<Vec<f64>> =
        (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
    let store = ColumnStore::from_cols(&cols, 3);
    let panel = build_panel(&store, &mut rng, k);

    let exact = gram_panel_seq(&store, &panel, CrossMode::Lazy);
    let fast = gram_panel_fast_seq(&store, &panel, CrossMode::Lazy);
    let mut max_err = 0.0f64;
    let mut scale = 0.0f64;
    for c in 0..k {
        for j in 0..ell {
            max_err = max_err.max((fast.atb_col(c)[j] - exact.atb_col(c)[j]).abs());
            scale = scale.max(exact.atb_col(c)[j].abs());
        }
        max_err = max_err.max((fast.btb(c) - exact.btb(c)).abs());
        scale = scale.max(exact.btb(c).abs());
    }
    // the driver's budget with the default fast_tol must hold here
    let budget = 1e-3 * scale.max(1.0);
    assert!(max_err > 0.0, "fast path suspiciously exact — is it routing to f64?");
    assert!(
        max_err <= budget,
        "fast kernel error {max_err:.3e} exceeds the default budget {budget:.3e}"
    );
}

#[test]
fn fast_fit_reports_budget_that_bounds_its_own_error() {
    // structured data with an exact vanishing ideal
    let m = 600usize;
    let mut rng = Rng::new(211);
    let mut x = avi_scale::linalg::dense::Matrix::zeros(m, 2);
    for i in 0..m {
        let t = rng.uniform() * 2.0 - 1.0;
        x.set(i, 0, t);
        x.set(i, 1, t * t + 0.01 * rng.normal());
    }

    let exact_cfg = OaviConfig::cgavi_ihb(0.01);
    let exact = Oavi::new(exact_cfg).fit(&x).unwrap();
    assert_eq!(exact.stats.numerics, NumericsMode::Exact);
    assert_eq!(exact.stats.fast_err_budget, 0.0, "exact fit must not sample a budget");

    let mut fast_cfg = OaviConfig::cgavi_ihb(0.01);
    fast_cfg.numerics = NumericsMode::Fast;
    let fast = Oavi::new(fast_cfg).fit(&x).unwrap();
    assert_eq!(fast.stats.numerics, NumericsMode::Fast);
    assert!(fast.stats.fast_err_budget > 0.0, "fast fit must report a budget");
    assert!(
        fast.stats.fast_max_abs_err <= fast.stats.fast_err_budget,
        "measured error {} exceeds reported budget {}",
        fast.stats.fast_max_abs_err,
        fast.stats.fast_err_budget
    );

    // fast is opt-in only: the default config never routes to f32
    assert_eq!(OaviConfig::cgavi_ihb(0.01).numerics, NumericsMode::Exact);

    // an unmeetable tolerance must fail the fit loudly, not degrade silently
    let mut strict_cfg = OaviConfig::cgavi_ihb(0.01);
    strict_cfg.numerics = NumericsMode::Fast;
    strict_cfg.fast_tol = 1e-300;
    let err = Oavi::new(strict_cfg).fit(&x);
    assert!(err.is_err(), "1e-300 budget should be unmeetable in f32");
    let msg = format!("{}", err.unwrap_err());
    assert!(msg.contains("error budget"), "unexpected error: {msg}");
}

// ---------------------------------------------------------------------
// exact fit invariance across kernel paths
// ---------------------------------------------------------------------

#[test]
fn exact_fit_is_bitwise_invariant_to_the_kernel_path_pin() {
    let _guard = THRESHOLD_LOCK.lock().unwrap();
    let ds = avi_scale::data::synthetic::synthetic_dataset(1500, 17);
    let x = ds.class_matrix(0);
    let cfg = OaviConfig::cgavi_ihb(0.01);
    let backend = NativeBackend;

    set_block_threshold_bytes(usize::MAX);
    let scalar = Oavi::new(cfg).fit_with_backend(&x, &backend).unwrap();
    set_block_threshold_bytes(1);
    let tiled = Oavi::new(cfg).fit_with_backend(&x, &backend).unwrap();
    set_block_threshold_bytes(BLOCK_THRESHOLD_DEFAULT);

    assert_eq!(scalar.generators.len(), tiled.generators.len());
    assert_eq!(scalar.o_terms.len(), tiled.o_terms.len());
    for (g0, g1) in scalar.generators.iter().zip(tiled.generators.iter()) {
        assert_eq!(g0.coeffs.len(), g1.coeffs.len());
        for (a, b) in g0.coeffs.iter().zip(g1.coeffs.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "generator coeffs diverge across kernel paths");
        }
    }
}

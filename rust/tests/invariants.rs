//! Cross-module property tests of the paper's theoretical claims —
//! Theorem 4.3 (termination degree + size bound), Theorem 4.9 (inverse
//! maintenance), Remark 4.5 (τ threshold), oracle-count accounting, and
//! solver-family agreement — on randomized instances.

use avi_scale::data::{load_registry_dataset, synthetic::synthetic_dataset};
use avi_scale::linalg::dense::Matrix;
use avi_scale::linalg::gram::GramState;
use avi_scale::oavi::{Oavi, OaviConfig};
use avi_scale::solvers::{GramProblem, SolverKind, SolverParams};
use avi_scale::util::proptest::{close, property};
use avi_scale::util::rng::Rng;

fn random_unit_data(rng: &mut Rng, m: usize, n: usize) -> Matrix {
    let mut x = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            x.set(i, j, rng.uniform());
        }
    }
    x
}

#[test]
fn theorem_4_3_size_bound_across_psi_and_n() {
    property(12, |rng| {
        let n = 1 + rng.below(4);
        let m = 50 + rng.below(100);
        let x = random_unit_data(rng, m, n);
        let psi = [0.5, 0.2, 0.05, 0.02][rng.below(4)];
        let cfg = OaviConfig::cgavi_ihb(psi);
        let model = Oavi::new(cfg).fit(&x).map_err(|e| e.to_string())?;
        let bound = cfg.size_bound(n);
        if (model.total_size() as f64) > bound {
            return Err(format!(
                "|G|+|O| = {} > C(D+n,D) = {bound} (psi {psi}, n {n})",
                model.total_size()
            ));
        }
        if model.stats.degree_reached > cfg.theorem_degree() {
            return Err(format!(
                "degree {} > D = {}",
                model.stats.degree_reached,
                cfg.theorem_degree()
            ));
        }
        Ok(())
    });
}

#[test]
fn oracle_call_accounting_matches_paper() {
    // §4.1: the solver is called exactly once per border term, for a total
    // of |G| + |O| − 1 calls.
    property(10, |rng| {
        let n = 1 + rng.below(3);
        let m = 60 + rng.below(60);
        let x = random_unit_data(rng, m, n);
        let cfg = OaviConfig::cgavi_ihb(0.05);
        let model = Oavi::new(cfg).fit(&x).map_err(|e| e.to_string())?;
        if model.stats.oracle_calls != model.total_size() - 1 {
            return Err(format!(
                "calls {} != |G|+|O|−1 = {}",
                model.stats.oracle_calls,
                model.total_size() - 1
            ));
        }
        Ok(())
    });
}

#[test]
fn ihb_inverse_stays_consistent_through_a_full_fit() {
    // Theorem 4.9 maintenance drift over a real fit on registry data
    let ds = load_registry_dataset("seeds", 1.0, 5).unwrap();
    for k in 0..ds.n_classes {
        let x = ds.class_matrix(k);
        let model = Oavi::new(OaviConfig::cgavi_ihb(0.002)).fit(&x).unwrap();
        // rebuild the Gram from the final O columns and compare inverses
        let store = model.o_terms.eval_store(&x, 3);
        let fresh = GramState::from_store(&store).unwrap();
        assert!(fresh.inverse_drift() < 1e-6);
    }
}

#[test]
fn solver_family_agrees_on_oavi_outputs() {
    // With interior optima (tau large), all four OAVI variants must find
    // the same generator structure on exact algebraic data.
    let ds = synthetic_dataset(800, 3);
    let x = ds.class_matrix(0);
    let psi = 0.005;
    let reference = Oavi::new(OaviConfig::cgavi_ihb(psi)).fit(&x).unwrap();
    for cfg in [
        OaviConfig::agdavi_ihb(psi),
        OaviConfig::bpcgavi_wihb(psi),
        OaviConfig::bpcgavi(psi),
        OaviConfig::pcgavi(psi),
        OaviConfig::cgavi(psi),
    ] {
        let model = Oavi::new(cfg).fit(&x).unwrap();
        assert_eq!(
            model.o_terms.len(),
            reference.o_terms.len(),
            "{}: |O| mismatch",
            cfg.name()
        );
        assert_eq!(
            model.generators.len(),
            reference.generators.len(),
            "{}: |G| mismatch",
            cfg.name()
        );
        for (a, b) in model.generators.iter().zip(reference.generators.iter()) {
            assert_eq!(a.leading, b.leading, "{}: leading term mismatch", cfg.name());
        }
    }
}

#[test]
fn remark_4_5_small_tau_disables_ihb_but_still_terminates() {
    // With τ barely above 2, (INF) fires and OAVI must fall back to the
    // constrained solver and still terminate with valid generators.
    let ds = synthetic_dataset(400, 7);
    let x = ds.class_matrix(0);
    let mut cfg = OaviConfig::cgavi_ihb(0.005);
    cfg.tau = 2.0;
    let model = Oavi::new(cfg).fit(&x).unwrap();
    // coefficients must respect the ball
    for g in &model.generators {
        let l1: f64 = g.coeffs.iter().map(|c| c.abs()).sum();
        assert!(l1 <= cfg.tau - 1.0 + 1e-6, "coeff ℓ1 {l1} > τ−1");
    }
    // with such a tight ball on curved data, (INF) must have fired
    assert!(model.stats.inf_disabled_ihb || model.generators.is_empty());
}

#[test]
fn gram_closed_form_equals_solver_across_instances() {
    property(12, |rng| {
        let m = 40 + rng.below(60);
        let ell = 1 + rng.below(6);
        let cols: Vec<Vec<f64>> =
            (0..ell).map(|_| (0..m).map(|_| rng.uniform()).collect()).collect();
        let b: Vec<f64> = (0..m).map(|_| rng.uniform() - 0.3).collect();
        let gram = GramState::from_columns(&cols).map_err(|e| e.to_string())?;
        let atb: Vec<f64> =
            cols.iter().map(|c| avi_scale::linalg::dot(c, &b)).collect();
        let btb = avi_scale::linalg::dot(&b, &b);
        let (y0, resid) = gram.solve_closed_form(&atb, btb);
        let p = GramProblem { b: gram.b(), atb: &atb, btb, m };
        let params = SolverParams { eps: 1e-10, max_iters: 30_000, radius: 1e6, psi: None };
        for solver in [SolverKind::Cg, SolverKind::Pcg, SolverKind::Bpcg, SolverKind::Agd] {
            let res = solver.solve(&p, &params);
            close(
                res.f,
                resid / m as f64,
                1e-4,
                &format!("{} vs closed form", solver.name()),
            )?;
        }
        let _ = y0;
        Ok(())
    });
}

//! Storage parity — the fit-level contract suite for the out-of-core
//! data plane (ISSUE 7):
//!
//! * **spill ≡ memory, bitwise**: for any fixed shard count, a fit on a
//!   spill-backed [`ColumnStore`] must produce bit-identical generators
//!   to the in-memory store — through the native backend and through the
//!   forced-parallel sharded backend with pinned shard counts.  The
//!   exact kernels read shard slices through leases either way; only
//!   where the bytes live may differ.
//! * **budget is honored**: ingesting a CSV larger than the resident
//!   budget and scanning the resulting store must keep the pool's
//!   high-water mark within budget, with the pressure visible in the
//!   eviction/reload counters (the ISSUE 7 acceptance criterion).
//! * **corruption is refused before compute**: a flipped byte in any
//!   segment must surface as a typed [`AviError::Storage`] at open time,
//!   so no fit ever runs on silently-corrupt data.
//!
//! Like the kernel suite, these tests run under both serial and default
//! test threading in `scripts/verify.sh` — every store here lives in its
//! own temp directory, so the suite must be order-independent.

use std::path::{Path, PathBuf};

use avi_scale::backend::{ComputeBackend, PinnedShards, ShardedBackend, StoreMode};
use avi_scale::error::AviError;
use avi_scale::linalg::dense::Matrix;
use avi_scale::oavi::{Oavi, OaviConfig, OaviModel};
use avi_scale::storage::{column_stats, ingest_csv, open_dataset, open_store, IngestOptions};
use avi_scale::util::proptest::property;
use avi_scale::util::rng::Rng;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("avi_storage_parity_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn random_unit_matrix(rng: &mut Rng, m: usize, n: usize) -> Matrix {
    let mut x = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            x.set(i, j, rng.uniform());
        }
    }
    x
}

/// Pin every per-generator quantity bitwise: leading terms, coefficient
/// vectors, and the reported MSEs (`to_bits`, not an epsilon).
fn assert_models_bitwise_equal(a: &OaviModel, b: &OaviModel, tag: &str) {
    assert_eq!(a.o_terms.len(), b.o_terms.len(), "{tag}: |O| differs");
    assert_eq!(a.generators.len(), b.generators.len(), "{tag}: |G| differs");
    for (ga, gb) in a.generators.iter().zip(&b.generators) {
        assert_eq!(ga.leading, gb.leading, "{tag}: leading term differs");
        assert_eq!(ga.mse.to_bits(), gb.mse.to_bits(), "{tag}: mse bits differ");
        assert_eq!(ga.coeffs.len(), gb.coeffs.len(), "{tag}: coeff count differs");
        for (ca, cb) in ga.coeffs.iter().zip(&gb.coeffs) {
            assert_eq!(ca.to_bits(), cb.to_bits(), "{tag}: coeff bits differ");
        }
    }
}

fn fit_pair(x: &Matrix, backend: &dyn ComputeBackend) -> (OaviModel, OaviModel) {
    let mem = Oavi::new(OaviConfig::cgavi_ihb(0.01)).fit_with_backend(x, backend).unwrap();
    let mut cfg = OaviConfig::cgavi_ihb(0.01);
    // a budget below the store's working set keeps the resident pool
    // under constant pressure — the harshest traffic pattern it supports
    cfg.store = StoreMode::Spill { budget_bytes: 2048 };
    let spill = Oavi::new(cfg).fit_with_backend(x, backend).unwrap();
    (mem, spill)
}

// ---------------------------------------------------------------------
// spill ≡ memory, bitwise
// ---------------------------------------------------------------------

#[test]
fn spill_fit_is_bitwise_equal_to_memory_native() {
    property(6, |rng| {
        let m = 40 + (rng.uniform() * 60.0) as usize;
        let n = 2 + (rng.uniform() * 2.0) as usize;
        let x = random_unit_matrix(rng, m, n);
        let (mem, spill) = fit_pair(&x, &avi_scale::backend::NativeBackend);
        assert!(spill.stats.store_spilled, "spill fit must report a spilled store");
        assert!(!mem.stats.store_spilled);
        assert!(spill.stats.store_loads > 0, "spilled fit must touch disk");
        assert_models_bitwise_equal(&mem, &spill, &format!("native m={m} n={n}"));
        Ok(())
    });
}

#[test]
fn spill_fit_is_bitwise_equal_to_memory_across_pinned_shard_counts() {
    let mut rng = Rng::new(11);
    let x = random_unit_matrix(&mut rng, 90, 3);
    // shard counts that leave uneven and single-row shards; min_work 0
    // forces the parallel reduction even at this size
    for shards in [1usize, 2, 3, 5, 8] {
        let be =
            PinnedShards::new(Box::new(ShardedBackend::new(3).with_min_work(0)), shards);
        let (mem, spill) = fit_pair(&x, &be);
        assert!(spill.stats.store_spilled);
        // eviction counts are scheduling-dependent here (concurrent
        // leases pin blocks past the budget); the deterministic
        // eviction contract lives in the ingest/scan test below
        assert!(spill.stats.store_loads > 0, "shards={shards}: spilled fit must touch disk");
        assert_models_bitwise_equal(&mem, &spill, &format!("sharded shards={shards}"));
    }
}

// ---------------------------------------------------------------------
// ingest → open under budget (the acceptance criterion)
// ---------------------------------------------------------------------

fn write_csv(path: &Path, rows: usize, feats: usize) {
    let mut s = String::new();
    s.push_str("f0");
    for j in 1..feats {
        s.push_str(&format!(",f{j}"));
    }
    s.push_str(",label\n");
    for i in 0..rows {
        for j in 0..feats {
            s.push_str(&format!("{},", (i * (j + 3)) as f64 / 97.0));
        }
        s.push_str(&format!("{}\n", i % 3));
    }
    std::fs::write(path, s).unwrap();
}

#[test]
fn ingest_larger_than_budget_stays_within_budget_under_scan() {
    let dir = tmp("budget");
    let csv = dir.join("big.csv");
    write_csv(&csv, 600, 4);
    let out = dir.join("ds");
    let opts = IngestOptions { name: "budget".into(), rows_per_shard: 64 };
    let man = ingest_csv(&csv, &out, &opts).unwrap();
    assert_eq!(man.rows, 600);
    assert!(man.segments.len() >= 9, "expected many segments, got {}", man.segments.len());

    // dataset bytes (600×5×8 = 24000) far exceed this resident budget;
    // one 64-row block is 2560 bytes, so at most one block fits
    let budget = 4096usize;
    assert!(man.rows * man.cols * 8 > budget);
    let (_, store) = open_store(&out, budget).unwrap();

    let stats = column_stats(&store);
    assert_eq!(stats.len(), man.cols);
    let c = store.backing_counters().expect("spill-backed store exposes counters");
    assert!(
        c.peak_resident_bytes <= budget as u64,
        "peak {} exceeds budget {budget}",
        c.peak_resident_bytes
    );
    assert!(c.evictions > 0, "scan over many segments under a one-block budget must evict");
    assert!(c.loads >= man.segments.len() as u64);

    // a second full scan re-reads evicted blocks: reloads must register
    let again = column_stats(&store);
    let c2 = store.backing_counters().unwrap();
    assert!(c2.reloads > 0, "second scan must reload evicted blocks");
    assert!(c2.peak_resident_bytes <= budget as u64);
    for (a, b) in stats.iter().zip(&again) {
        assert_eq!(a.min.to_bits(), b.min.to_bits());
        assert_eq!(a.max.to_bits(), b.max.to_bits());
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
    }
}

// ---------------------------------------------------------------------
// corruption is refused before compute
// ---------------------------------------------------------------------

#[test]
fn corrupt_segment_fails_open_with_typed_storage_error() {
    let dir = tmp("corrupt");
    let csv = dir.join("d.csv");
    write_csv(&csv, 40, 3);
    let out = dir.join("ds");
    let opts = IngestOptions { name: "corrupt".into(), rows_per_shard: 16 };
    ingest_csv(&csv, &out, &opts).unwrap();

    // sanity: pristine dataset opens and fits
    let ds = open_dataset(&out, 0).unwrap();
    Oavi::new(OaviConfig::cgavi_ihb(0.05)).fit(&ds.x).unwrap();

    let victim = out.join("seg_1.bin");
    let mut bytes = std::fs::read(&victim).unwrap();
    bytes[8] ^= 0x40;
    std::fs::write(&victim, &bytes).unwrap();

    for res in [
        open_dataset(&out, 0).map(|_| ()),
        open_store(&out, 0).map(|_| ()),
    ] {
        match res {
            Err(AviError::Storage(msg)) => {
                assert!(msg.contains("seg_1.bin"), "error must name the segment: {msg}");
                assert!(msg.contains("checksum"), "error must say why: {msg}");
            }
            other => panic!("corrupt open must fail with AviError::Storage: {other:?}"),
        }
    }
}

//! Failure injection & adversarial inputs: the framework must degrade
//! gracefully (clean errors or sane output), never panic or hang, on
//! hostile data.

use avi_scale::baselines::abm::{Abm, AbmConfig};
use avi_scale::baselines::vca::{Vca, VcaConfig};
use avi_scale::linalg::dense::Matrix;
use avi_scale::oavi::{Oavi, OaviConfig};
use avi_scale::ordering::{order_features, FeatureOrdering};
use avi_scale::svm::linear::{LinearSvm, LinearSvmConfig};
use avi_scale::util::rng::Rng;

fn constant_data(m: usize, n: usize, v: f64) -> Matrix {
    let mut x = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            x.set(i, j, v);
        }
    }
    x
}

#[test]
fn constant_zero_data_terminates_quickly() {
    // x_j ≡ 0: every degree-1 monomial vanishes exactly; O stays {1}.
    let x = constant_data(50, 3, 0.0);
    let model = Oavi::new(OaviConfig::cgavi_ihb(1e-6)).fit(&x).unwrap();
    assert_eq!(model.o_terms.len(), 1);
    assert_eq!(model.generators.len(), 3);
    for g in &model.generators {
        assert!(g.mse <= 1e-6);
    }
}

#[test]
fn constant_one_data_is_handled() {
    // x_j ≡ 1: columns equal the constant column — maximal degeneracy.
    let x = constant_data(50, 3, 1.0);
    let model = Oavi::new(OaviConfig::cgavi_ihb(1e-6)).fit(&x).unwrap();
    // x_j − 1 vanishes exactly ⇒ all degree-1 terms become generators
    assert_eq!(model.generators.len(), 3);
    assert_eq!(model.o_terms.len(), 1);
}

#[test]
fn single_sample_fits() {
    let x = Matrix::from_rows(&[vec![0.3, 0.7]]).unwrap();
    for cfg in [OaviConfig::cgavi_ihb(0.01), OaviConfig::bpcgavi(0.01)] {
        let model = Oavi::new(cfg).fit(&x).unwrap();
        assert!(model.total_size() >= 1);
    }
    assert!(Abm::new(AbmConfig::new(0.01)).fit(&x).is_ok());
    assert!(Vca::new(VcaConfig::new(0.01)).fit(&x).is_ok());
}

#[test]
fn single_feature_fits() {
    let mut rng = Rng::new(1);
    let rows: Vec<Vec<f64>> = (0..40).map(|_| vec![rng.uniform()]).collect();
    let x = Matrix::from_rows(&rows).unwrap();
    let model = Oavi::new(OaviConfig::cgavi_ihb(0.01)).fit(&x).unwrap();
    assert!(model.stats.degree_reached >= 1);
}

#[test]
fn near_zero_psi_on_exact_variety_is_stable() {
    // ψ at the f64 cancellation floor with data exactly on a line: IHB
    // must find the exact generator without Schur failures cascading.
    // (ψ = 0 exactly is the theoretical case — floating-point residuals
    // of exact relations land at ~1e-15, which is why the paper's
    // practical setting is ψ > 0.)
    let rows: Vec<Vec<f64>> = (0..60)
        .map(|i| {
            let t = i as f64 / 59.0;
            vec![t, 1.0 - t]
        })
        .collect();
    let x = Matrix::from_rows(&rows).unwrap();
    let model = Oavi::new(OaviConfig::cgavi_ihb(1e-14)).fit(&x).unwrap();
    // x0 + x1 − 1 = 0 is degree 1 ⇒ a degree-1 generator exists
    assert!(model.generators.iter().any(|g| g.degree() == 1));
    let gs = model.generator_set();
    // the closed-form residual is exact in exact arithmetic; recomputing
    // ‖Ac+b‖²/m directly from an ill-conditioned (near-dependent) system
    // can drift a few orders above the f64 floor — anything ≪ practical ψ
    // values is fine.
    for mse in gs.mse_on(&x) {
        assert!(mse < 1e-6, "exact generator has mse {mse}");
    }
    // ψ = 0 exactly must still terminate without panicking
    let strict = Oavi::new(OaviConfig::cgavi_ihb(0.0)).fit(&x).unwrap();
    assert!(strict.stats.degree_reached <= OaviConfig::cgavi_ihb(0.0).max_degree);
}

#[test]
fn extreme_psi_values() {
    let mut rng = Rng::new(2);
    let rows: Vec<Vec<f64>> = (0..50)
        .map(|_| vec![rng.uniform(), rng.uniform()])
        .collect();
    let x = Matrix::from_rows(&rows).unwrap();
    // ψ ≥ 1: everything vanishes at degree 1 (x ∈ [0,1] ⇒ MSE(x_j) ≤ 1)
    let loose = Oavi::new(OaviConfig::cgavi_ihb(1.0)).fit(&x).unwrap();
    assert_eq!(loose.o_terms.len(), 1);
    // negative ψ rejected by validation
    assert!(Oavi::new(OaviConfig::cgavi_ihb(-0.1)).fit(&x).is_err());
    // NaN ψ rejected
    assert!(Oavi::new(OaviConfig::cgavi_ihb(f64::NAN)).fit(&x).is_err());
}

#[test]
fn duplicated_and_correlated_features_dont_blow_up() {
    let mut rng = Rng::new(3);
    let mut rows = Vec::new();
    for _ in 0..80 {
        let t = rng.uniform();
        rows.push(vec![t, t, t, 2.0 * t - t]); // three exact duplicates
    }
    let x = Matrix::from_rows(&rows).unwrap();
    let model = Oavi::new(OaviConfig::cgavi_ihb(1e-12)).fit(&x).unwrap();
    // pairwise differences vanish: at least 3 degree-1 generators
    let deg1 = model.generators.iter().filter(|g| g.degree() == 1).count();
    assert!(deg1 >= 3, "found {deg1} degree-1 generators");
}

#[test]
fn ordering_handles_constant_and_nan_free_data() {
    // constant feature has zero variance ⇒ Pearson 0 by convention
    let mut rows = Vec::new();
    let mut rng = Rng::new(4);
    for _ in 0..30 {
        rows.push(vec![0.5, rng.uniform()]);
    }
    let x = Matrix::from_rows(&rows).unwrap();
    let perm = order_features(&x, FeatureOrdering::Pearson);
    assert_eq!(perm.len(), 2);
}

#[test]
fn svm_on_single_class_labels_errors() {
    let x = constant_data(10, 2, 0.5);
    assert!(LinearSvm::fit(&x, &vec![0; 10], 1, LinearSvmConfig::default()).is_err());
}

#[test]
fn svm_on_degenerate_features_is_finite() {
    // all-zero features: the SVM must converge to the bias-only solution
    let x = constant_data(40, 3, 0.0);
    let y: Vec<usize> = (0..40).map(|i| i % 2).collect();
    let svm = LinearSvm::fit(&x, &y, 2, LinearSvmConfig::default()).unwrap();
    for (w, b) in &svm.weights {
        assert!(w.iter().all(|v| v.is_finite()));
        assert!(b.is_finite());
    }
}

#[test]
fn tiny_tau_never_panics_across_solvers() {
    let mut rng = Rng::new(5);
    let rows: Vec<Vec<f64>> = (0..60)
        .map(|_| vec![rng.uniform(), rng.uniform(), rng.uniform()])
        .collect();
    let x = Matrix::from_rows(&rows).unwrap();
    for mut cfg in [
        OaviConfig::cgavi_ihb(0.01),
        OaviConfig::bpcgavi(0.01),
        OaviConfig::pcgavi(0.01),
    ] {
        cfg.tau = 2.0; // minimum legal
        let model = Oavi::new(cfg).fit(&x).unwrap();
        for g in &model.generators {
            let l1: f64 = g.coeffs.iter().map(|c| c.abs()).sum();
            assert!(l1 <= 1.0 + 1e-6, "{}: coeffs left the ball: {l1}", cfg.name());
        }
    }
}

#[test]
fn max_degree_cap_terminates_adversarial_config() {
    // ψ so small nothing vanishes on random data: the degree cap (and
    // max_o_terms) must still terminate the fit in bounded work.
    let mut rng = Rng::new(6);
    let rows: Vec<Vec<f64>> = (0..30).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
    let x = Matrix::from_rows(&rows).unwrap();
    let mut cfg = OaviConfig::cgavi_ihb(1e-300);
    cfg.max_degree = 3;
    cfg.max_o_terms = 50;
    let model = Oavi::new(cfg).fit(&x).unwrap();
    assert!(model.stats.degree_reached <= 3);
    assert!(model.o_terms.len() <= 50);
}

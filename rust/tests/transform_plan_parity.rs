//! Compiled transform-plan parity — the serving-plan contract:
//!
//! * **prepared ↔ legacy, bitwise**: a [`TransformPlan`] compiled from a
//!   fitted pipeline must reproduce `predict_scores_with_backend`
//!   **bit-for-bit** for every estimator method, against the native
//!   backend and against every pinned store shard count.  The transform
//!   is per-row independent, so shard count never changes bits — which
//!   is exactly why the service may route small flushes through the
//!   plan and large ones through the sharded legacy path without the
//!   answer depending on the split.
//! * **concatenation**: per-class prepared transforms writing directly
//!   into their column ranges of one slab must equal the legacy
//!   per-class block concatenation.
//! * **sparse kernel**: the packed-column kernel is opt-in and gated;
//!   when forced on it must stay within an explicit error budget of the
//!   dense exact path (the only arithmetic difference is skipping
//!   `a_ij * 0.0` terms, which can only flip signed zeros before the
//!   final `abs`).
//! * **hot swap**: a mid-traffic swap serves the new generation from a
//!   freshly adopted plan (plan counters prove no cold rebuild on the
//!   request path).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use avi_scale::backend::{NativeBackend, PinnedShards, ShardedBackend};
use avi_scale::coordinator::registry::ModelRegistry;
use avi_scale::coordinator::router::ModelRouter;
use avi_scale::coordinator::service::{ServeConfig, ServeRequest};
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::estimator::plan::PlanPolicy;
use avi_scale::estimator::EstimatorConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::plan::{TransformPlan, TransformScratch};
use avi_scale::pipeline::{train_pipeline, PipelineConfig, PipelineModel};
use avi_scale::svm::linear::LinearSvmConfig;

const METHODS: [&str; 8] = [
    "cgavi-ihb",
    "agdavi-ihb",
    "bpcgavi-wihb",
    "bpcgavi",
    "pcgavi",
    "cgavi",
    "abm",
    "vca",
];

fn trained(method: &str, psi: f64, seed: u64) -> Arc<PipelineModel> {
    let ds = synthetic_dataset(300, seed);
    let cfg = PipelineConfig {
        estimator: EstimatorConfig::parse(method, psi).unwrap(),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    Arc::new(train_pipeline(&cfg, &ds).unwrap())
}

fn score_bits(scores: &[Vec<f64>]) -> Vec<Vec<u64>> {
    scores.iter().map(|row| row.iter().map(|v| v.to_bits()).collect()).collect()
}

#[test]
fn methods_list_covers_every_known_estimator() {
    // keep the parity battery in sync with the estimator registry
    let known = EstimatorConfig::known_methods();
    assert_eq!(known.len(), METHODS.len(), "estimator registry changed: {known:?}");
    for m in METHODS {
        assert!(known.contains(&m), "parity battery is missing '{m}'");
    }
}

#[test]
fn prepared_plan_is_bitwise_identical_to_legacy_for_every_method_and_shard_count() {
    let probe = synthetic_dataset(53, 17);
    for method in METHODS {
        let model = trained(method, 0.01, 9);
        let plan = TransformPlan::build(Arc::clone(&model), &PlanPolicy::default());
        let mut scratch = TransformScratch::new();
        let (plan_labels, plan_scores) = plan.predict_scores(&probe.x, &mut scratch);
        let plan_bits = score_bits(&plan_scores);

        // native reference
        let (labels, scores) = model.predict_scores_with_backend(&probe.x, &NativeBackend);
        assert_eq!(plan_labels, labels, "{method}: native labels diverged");
        assert_eq!(plan_bits, score_bits(&scores), "{method}: native score bits diverged");

        // every pinned store shard count, sequential and pool-sharded
        for &shards in &[1usize, 2, 3, 5, 8] {
            let native_pin = PinnedShards::new(Box::new(NativeBackend), shards);
            let sharded_pin =
                PinnedShards::new(Box::new(ShardedBackend::new(3).with_min_work(0)), shards);
            let pinned: [(&str, &dyn avi_scale::backend::ComputeBackend); 2] =
                [("native", &native_pin), ("sharded", &sharded_pin)];
            for (tag, backend) in pinned {
                let (labels, scores) = model.predict_scores_with_backend(&probe.x, backend);
                assert_eq!(
                    plan_labels, labels,
                    "{method}: labels diverged ({tag}, shards={shards})"
                );
                assert_eq!(
                    plan_bits,
                    score_bits(&scores),
                    "{method}: score bits diverged ({tag}, shards={shards})"
                );
            }
        }
    }
}

#[test]
fn per_class_plans_write_the_same_concatenation_as_the_legacy_transform() {
    // multi-class model → several class blocks → exercises the direct
    // column-range writes of both paths
    let model = trained("cgavi-ihb", 0.01, 21);
    let transformer = &model.transformer;
    let probe = synthetic_dataset(31, 5);
    let legacy = transformer.transform_with(&probe.x, &NativeBackend);

    let policy = PlanPolicy::default();
    let total = transformer.n_generators();
    let mut slab = vec![0.0f64; probe.x.rows() * total];
    let mut scratch = avi_scale::estimator::plan::PlanScratch::new();
    let mut off = 0;
    for class in &transformer.per_class {
        let prepared = class.prepare(&policy);
        prepared.transform_into(&probe.x, &mut scratch, &mut slab, total, off);
        off += prepared.n_cols();
    }
    assert_eq!(off, total, "class column ranges must tile the slab exactly");
    for i in 0..probe.x.rows() {
        for j in 0..total {
            assert_eq!(
                slab[i * total + j].to_bits(),
                legacy.get(i, j).to_bits(),
                "concatenated cell ({i}, {j}) diverged"
            );
        }
    }
}

#[test]
fn forced_sparse_kernel_stays_within_the_error_budget() {
    // force engagement regardless of measured density: threshold 0.0
    let forced = PlanPolicy { sparse: true, sparse_min_zero_frac: 0.0 };
    let probe = synthetic_dataset(47, 13);
    for method in ["cgavi-ihb", "bpcgavi-wihb", "abm"] {
        let model = trained(method, 0.01, 9);
        let dense = TransformPlan::build(Arc::clone(&model), &PlanPolicy::default());
        let sparse = TransformPlan::build(Arc::clone(&model), &forced);
        assert!(!dense.sparse_engaged(), "{method}: dense default engaged sparse");
        assert!(sparse.sparse_engaged(), "{method}: forced policy did not engage");

        let mut ds_scratch = TransformScratch::new();
        let mut sp_scratch = TransformScratch::new();
        let (dense_labels, dense_scores) = dense.predict_scores(&probe.x, &mut ds_scratch);
        let (sparse_labels, sparse_scores) = sparse.predict_scores(&probe.x, &mut sp_scratch);
        // the kernels differ only in skipped zero multiplies: scores must
        // agree to well under any decision margin
        for (a, b) in dense_scores.iter().zip(sparse_scores.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert!(
                    (x - y).abs() <= 1e-12,
                    "{method}: sparse kernel drifted {x} vs {y}"
                );
            }
        }
        assert_eq!(dense_labels, sparse_labels, "{method}: labels flipped");
    }

    // default-threshold opt-in: engagement may or may not trigger on this
    // model, but gating must follow the measured density deterministically
    let model = trained("cgavi-ihb", 0.01, 9);
    let a = TransformPlan::build(Arc::clone(&model), &PlanPolicy::sparse_enabled());
    let b = TransformPlan::build(Arc::clone(&model), &PlanPolicy::sparse_enabled());
    assert_eq!(a.sparse_classes(), b.sparse_classes(), "gating must be deterministic");
}

#[test]
fn hot_swap_mid_traffic_serves_the_new_generation_from_a_fresh_plan() {
    let ds = synthetic_dataset(24, 19);
    let mut registry = ModelRegistry::new();
    registry.insert("m", "v1", trained("cgavi-ihb", 0.01, 9)).unwrap();
    registry.insert("m", "v2", trained("cgavi-ihb", 0.01, 9)).unwrap();

    let router = ModelRouter::new();
    let gate = Arc::new(AtomicBool::new(true));
    let held = ServeConfig { hold_gate: Some(gate.clone()), ..ServeConfig::default() };
    router
        .register_ab(&registry, "m", &[("v1".into(), 100)], 0, &held)
        .unwrap();

    // admitted to v1 while its batcher is gated — in flight across the swap
    let pending = router.enqueue("m", ServeRequest::row(ds.x.row(0).to_vec())).unwrap();

    // hot swap to v2: the arm adopts the plan the registry compiled at
    // insert, so the new generation is warmed before taking traffic
    router
        .register_ab(&registry, "m", &[("v2".into(), 100)], 0, &ServeConfig::default())
        .unwrap();
    for i in 1..ds.x.rows() {
        let ans = router.predict("m", ds.x.row(i).to_vec()).unwrap();
        assert_eq!(ans.model_version, "v2");
    }

    // release the old generation; the in-flight request is still answered
    // by (and stamped with) v1
    gate.store(false, Ordering::SeqCst);
    let ans = pending.wait().answer().unwrap();
    assert_eq!(ans.model_version, "v1");

    let report = router.report();
    let v2 = report
        .routes
        .iter()
        .find(|r| r.role == "primary" && r.version == "v2")
        .expect("live v2 arm");
    assert_eq!(v2.plan_builds, 1, "new generation must start exactly one plan");
    assert!(v2.plan_hits > 0, "new generation never served from its plan");
    let v1 = report
        .routes
        .iter()
        .find(|r| r.role == "retired" && r.version == "v1")
        .expect("retired v1 arm");
    assert_eq!(v1.plan_builds, 1, "old generation had its own plan");
    assert!(v1.plan_hits > 0, "drained in-flight request must go through v1's plan");
}

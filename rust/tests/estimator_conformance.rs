//! Estimator-trait conformance: every registered estimator (OAVI
//! variants, ABM, VCA) must pass the same contract through the unified
//! surface — fit → transform → persist round-trip — under both the
//! native and the sharded backend.  This is the acceptance gate of the
//! estimator-layer redesign: a new constructor that implements
//! `VanishingIdealEstimator` + `FittedModel` inherits this suite by
//! being added to `EstimatorConfig`.

use avi_scale::artifact;
use avi_scale::backend::{ComputeBackend, NativeBackend, ShardedBackend};
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::estimator::persist::{
    load_model, model_from_bytes, model_from_json, model_to_json, pipeline_from_bytes,
    pipeline_from_json, pipeline_to_json, save_model,
};
use avi_scale::estimator::EstimatorConfig;
use avi_scale::linalg::dense::Matrix;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::{train_pipeline_with_backend, PipelineConfig};
use avi_scale::svm::linear::LinearSvmConfig;

fn bits(m: &Matrix) -> Vec<u64> {
    m.data().iter().map(|v| v.to_bits()).collect()
}

fn backends() -> Vec<(&'static str, Box<dyn ComputeBackend>)> {
    vec![
        ("native", Box::new(NativeBackend)),
        ("sharded", Box::new(ShardedBackend::with_min_rows(3, 64))),
    ]
}

/// Every method name resolves, fits under both backends, transforms with
/// a consistent shape, and survives a JSON round-trip with a bitwise-
/// identical transform.
#[test]
fn every_estimator_conforms_under_every_backend() {
    let ds = synthetic_dataset(600, 41);
    let x = ds.class_matrix(0);
    let z = ds.class_matrix(1);
    for name in EstimatorConfig::known_methods() {
        // ψ loose enough that every variant (cold-start solvers included)
        // certifies vanishing generators on the noisy quadric data
        let cfg = EstimatorConfig::parse(name, 0.05).unwrap();
        for (bname, backend) in backends() {
            let model = cfg
                .fit(&x, backend.as_ref())
                .unwrap_or_else(|e| panic!("{name}/{bname}: fit failed: {e}"));
            let report = model.report();
            assert_eq!(report.name(), cfg.name(), "{name}/{bname}: report name");
            assert!(report.wall_secs > 0.0, "{name}/{bname}: FitReport has no wall-clock");
            assert!(model.n_generators() > 0, "{name}/{bname}: nothing vanished");
            assert!(model.total_size() >= model.n_generators());

            // transform: shape + non-negativity (these are |g(z)| blocks)
            let t = model.transform_with(&z, backend.as_ref());
            assert_eq!(t.rows(), z.rows(), "{name}/{bname}");
            assert_eq!(t.cols(), model.n_generators(), "{name}/{bname}");
            assert!(t.data().iter().all(|v| *v >= 0.0), "{name}/{bname}: negative |g|");

            // persist round-trip: bitwise-equal transform on a fixed set
            let json = model_to_json(model.as_ref());
            let back = model_from_json(&json)
                .unwrap_or_else(|e| panic!("{name}/{bname}: reload failed: {e}"));
            assert_eq!(back.report().name(), cfg.name());
            assert_eq!(back.n_generators(), model.n_generators());
            assert_eq!(back.total_size(), model.total_size());
            let tb = back.transform_with(&z, backend.as_ref());
            assert_eq!(bits(&t), bits(&tb), "{name}/{bname}: reloaded transform differs");
        }
    }
}

/// Whole-pipeline persistence through the same envelope: every estimator
/// (including VCA, which the old path rejected) predicts identically
/// after save → load.
#[test]
fn pipeline_envelope_roundtrips_every_estimator() {
    let ds = synthetic_dataset(400, 43);
    let probe = synthetic_dataset(60, 44);
    for est in EstimatorConfig::battery(0.01) {
        let cfg = PipelineConfig {
            estimator: est,
            svm: LinearSvmConfig::default(),
            ordering: FeatureOrdering::Pearson,
        };
        let model = train_pipeline_with_backend(&cfg, &ds, &NativeBackend)
            .unwrap_or_else(|e| panic!("{}: {e}", est.name()));
        let json = pipeline_to_json(&model);
        let back = pipeline_from_json(&json).unwrap_or_else(|e| panic!("{}: {e}", est.name()));
        assert_eq!(back.transformer.method_name, est.name());
        assert_eq!(back.perm, model.perm);
        assert_eq!(back.transformer.total_size(), model.transformer.total_size());
        assert_eq!(
            back.predict(&probe.x),
            model.predict(&probe.x),
            "{}: predictions diverge after round-trip",
            est.name()
        );
    }
}

/// File-level round-trip and cross-backend serving equivalence: a model
/// fitted natively, persisted, reloaded, and transformed through the
/// sharded backend must agree with the in-memory native transform.
#[test]
fn persisted_models_serve_identically_across_backends() {
    let ds = synthetic_dataset(500, 47);
    let x = ds.class_matrix(0);
    let z = ds.class_matrix(1);
    let dir = std::env::temp_dir().join("avi_scale_conformance");
    for est in EstimatorConfig::battery(0.005) {
        let model = est.fit(&x, &NativeBackend).unwrap();
        let path = dir.join(format!("{}.json", est.name().to_lowercase()));
        save_model(model.as_ref(), &path).unwrap();
        let back = load_model(&path).unwrap();
        let reference = model.transform_with(&z, &NativeBackend);
        // small m ⇒ sharded backends fall back to single-shard stores,
        // which the data-plane contract makes bit-identical to native
        let sharded = ShardedBackend::new(4);
        let served = back.transform_with(&z, &sharded);
        assert_eq!(
            bits(&reference),
            bits(&served),
            "{}: persisted+sharded transform differs",
            est.name()
        );
    }
}

/// Cross-codec interchangeability (the PR-2 follow-up): the JSON
/// envelope and the binary AVIB artifact are two encodings of the same
/// payload behind one version gate.  For every estimator, JSON → binary
/// → JSON reproduces the envelope **byte for byte**, and the reloaded
/// model transforms bitwise identically; the binary side is also
/// strictly smaller.
#[test]
fn json_and_binary_codecs_are_interchangeable_bitwise() {
    let ds = synthetic_dataset(400, 53);
    let x = ds.class_matrix(0);
    let z = ds.class_matrix(1);
    for est in EstimatorConfig::battery(0.01) {
        // model-level envelope
        let model = est.fit(&x, &NativeBackend).unwrap();
        let json = model_to_json(model.as_ref());
        let from_json = model_from_bytes(json.as_bytes()).unwrap();
        let bin = artifact::encode_model(from_json.as_ref()).unwrap();
        let from_bin = model_from_bytes(&bin).unwrap();
        assert!(
            artifact::codec::is_binary(&bin) && !artifact::codec::is_binary(json.as_bytes()),
            "{}: version gate must tell the codecs apart",
            est.name()
        );
        assert_eq!(
            model_to_json(from_bin.as_ref()),
            model_to_json(from_json.as_ref()),
            "{}: JSON -> binary -> JSON is not byte-identical",
            est.name()
        );
        let t = model.transform_with(&z, &NativeBackend);
        let tb = from_bin.transform_with(&z, &NativeBackend);
        assert_eq!(bits(&t), bits(&tb), "{}: cross-codec transform differs", est.name());
        assert!(
            bin.len() < json.len(),
            "{}: binary ({}) must be smaller than JSON ({})",
            est.name(),
            bin.len(),
            json.len()
        );
    }

    // pipeline-level envelope, through the same gate
    let cfg = PipelineConfig {
        estimator: EstimatorConfig::battery(0.01)[0],
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    let pds = synthetic_dataset(400, 54);
    let probe = synthetic_dataset(60, 55);
    let model = train_pipeline_with_backend(&cfg, &pds, &NativeBackend).unwrap();
    let json = pipeline_to_json(&model);
    let from_json = pipeline_from_bytes(json.as_bytes()).unwrap();
    let bin = artifact::encode_pipeline(&from_json).unwrap();
    let from_bin = pipeline_from_bytes(&bin).unwrap();
    assert_eq!(
        pipeline_to_json(&from_bin),
        pipeline_to_json(&from_json),
        "pipeline: JSON -> binary -> JSON is not byte-identical"
    );
    let (la, sa) = model.predict_scores_with_backend(&probe.x, &NativeBackend);
    let (lb, sb) = from_bin.predict_scores_with_backend(&probe.x, &NativeBackend);
    assert_eq!(la, lb, "pipeline: cross-codec labels diverge");
    for (ra, rb) in sa.iter().zip(&sb) {
        let rbits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(rbits(ra), rbits(rb), "pipeline: cross-codec score bits diverge");
    }
    assert!(bin.len() < json.len(), "pipeline: binary must be smaller than JSON");
}

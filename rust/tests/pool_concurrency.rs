//! Concurrency property suite for the persistent work-stealing pool —
//! the contract ISSUE 3 pins:
//!
//! * every job runs exactly once, results in submission order, under
//!   randomized job durations;
//! * nested submission (inner jobs submitted from inside outer jobs over
//!   a shared [`PoolHandle`]) does not deadlock, even with more nested
//!   batches than workers;
//! * a panic in one job surfaces as an `Err` in that job's slot while
//!   the remaining jobs complete and the workers survive;
//! * dropping the pool drains queued jobs and joins all workers;
//! * the adaptive-threshold fallback and the forced pool fan-out are
//!   both bit-identical to [`NativeBackend`].
//!
//! `scripts/verify.sh` runs this binary twice — `RUST_TEST_THREADS=1`
//! (serial, stable schedules) and the default multi-thread mode — so
//! scheduling-order bugs reproduce under both regimes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use avi_scale::backend::{ColumnStore, ComputeBackend, NativeBackend, ShardedBackend};
use avi_scale::coordinator::pool::{Job, PoolHandle, ThreadPool};
use avi_scale::util::proptest::property;
use avi_scale::util::rng::Rng;

/// Run `f` on a helper thread and fail the test (instead of hanging CI)
/// if it has not finished within `secs`.
fn with_deadline<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("pool operation deadlocked or timed out")
}

#[test]
fn every_job_runs_exactly_once_in_submission_order_under_random_durations() {
    property(8, |rng| {
        let n = 1 + rng.below(50);
        let workers = 1 + rng.below(5);
        let durations: Vec<u64> = (0..n).map(|_| rng.below(300) as u64).collect();
        let counters: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let pool = ThreadPool::new(workers);
        let jobs: Vec<Job<'static, usize>> = (0..n)
            .map(|i| {
                let c = Arc::clone(&counters);
                let us = durations[i];
                Box::new(move || {
                    std::thread::sleep(Duration::from_micros(us));
                    c[i].fetch_add(1, Ordering::SeqCst);
                    i * 3 + 1
                }) as Job<'static, usize>
            })
            .collect();
        let out = pool.run_all(jobs);
        for (i, c) in counters.iter().enumerate() {
            let runs = c.load(Ordering::SeqCst);
            if runs != 1 {
                return Err(format!("job {i} ran {runs} times (workers {workers})"));
            }
        }
        let expect: Vec<usize> = (0..n).map(|i| i * 3 + 1).collect();
        if out != expect {
            return Err(format!("order not preserved (n {n}, workers {workers})"));
        }
        Ok(())
    });
}

#[test]
fn nested_submission_does_not_deadlock() {
    // more outer jobs than workers, each submitting an inner batch over
    // the same shared handle: without the helping loop this wedges on a
    // 2-worker pool
    let total: usize = with_deadline(60, || {
        let pool = ThreadPool::new(2);
        let handle = pool.handle();
        let outer_jobs: Vec<Job<'static, usize>> = (0..6usize)
            .map(|o| {
                let h: PoolHandle = handle.clone();
                Box::new(move || {
                    let inner_jobs: Vec<Job<'static, usize>> = (0..8usize)
                        .map(|i| Box::new(move || o * 100 + i) as Job<'static, usize>)
                        .collect();
                    h.run_all(inner_jobs).into_iter().sum::<usize>()
                }) as Job<'static, usize>
            })
            .collect();
        let sums = pool.run_all(outer_jobs);
        assert_eq!(sums.len(), 6);
        for (o, s) in sums.iter().enumerate() {
            assert_eq!(*s, o * 800 + 28, "outer {o} inner sum");
        }
        sums.into_iter().sum()
    });
    assert_eq!(total, (0..6).map(|o| o * 800 + 28).sum::<usize>());
}

#[test]
fn doubly_nested_submission_does_not_deadlock() {
    // three levels of 2-job batches on a single worker: only the helping
    // loop can make progress, which is exactly what this pins
    let v: usize = with_deadline(60, || {
        let pool = ThreadPool::new(1);
        let handle = pool.handle();
        let outer: Vec<Job<'static, usize>> = (0..2usize)
            .map(|o| {
                let h1 = handle.clone();
                Box::new(move || {
                    let mid: Vec<Job<'static, usize>> = (0..2usize)
                        .map(|m| {
                            let h2 = h1.clone();
                            Box::new(move || {
                                let inner: Vec<Job<'static, usize>> = (0..2usize)
                                    .map(|i| {
                                        Box::new(move || o * 100 + m * 10 + i)
                                            as Job<'static, usize>
                                    })
                                    .collect();
                                h2.run_all(inner).into_iter().sum::<usize>()
                            }) as Job<'static, usize>
                        })
                        .collect();
                    h1.run_all(mid).into_iter().sum::<usize>()
                }) as Job<'static, usize>
            })
            .collect();
        pool.run_all(outer).into_iter().sum()
    });
    // Σ over o,m,i of (100o + 10m + i) = 400·1 + 40·1 + 2·2 = 444
    assert_eq!(v, 444);
}

#[test]
fn panic_in_one_job_surfaces_as_error_while_rest_complete() {
    let pool = ThreadPool::new(3);
    let completed = Arc::new(AtomicUsize::new(0));
    let jobs: Vec<Job<'static, usize>> = (0..20usize)
        .map(|i| {
            let c = Arc::clone(&completed);
            Box::new(move || {
                if i == 7 {
                    panic!("intentional test panic in job {i}");
                }
                c.fetch_add(1, Ordering::SeqCst);
                i
            }) as Job<'static, usize>
        })
        .collect();
    let out = pool.try_run_all(jobs);
    assert_eq!(out.len(), 20);
    assert_eq!(completed.load(Ordering::SeqCst), 19, "remaining jobs must complete");
    for (i, r) in out.iter().enumerate() {
        if i == 7 {
            let msg = r.as_ref().expect_err("slot 7 must be poisoned");
            assert!(msg.contains("intentional test panic"), "unexpected message {msg}");
        } else {
            assert_eq!(*r.as_ref().expect("non-panicking slot"), i);
        }
    }
    // workers survived: the same pool still serves batches in order
    let again: Vec<usize> =
        pool.run_all((0..10usize).map(|i| Box::new(move || i) as Job<'static, usize>).collect());
    assert_eq!(again, (0..10).collect::<Vec<usize>>());
}

#[test]
fn drop_joins_all_workers_and_drains_in_flight_batches() {
    let pool = ThreadPool::new(3);
    let handle = pool.handle();
    assert_eq!(handle.live_workers(), 3);
    let counter = Arc::new(AtomicUsize::new(0));
    let c = Arc::clone(&counter);
    let h = handle.clone();
    // a concurrent submitter keeps a slow batch in flight while we drop
    let submitter = std::thread::spawn(move || {
        let jobs: Vec<Job<'static, usize>> = (0..24usize)
            .map(|i| {
                let c = Arc::clone(&c);
                Box::new(move || {
                    std::thread::sleep(Duration::from_millis(2));
                    c.fetch_add(1, Ordering::SeqCst);
                    i
                }) as Job<'static, usize>
            })
            .collect();
        h.run_all(jobs)
    });
    std::thread::sleep(Duration::from_millis(5));
    drop(pool); // graceful: drains the queue, then joins every worker
    assert_eq!(handle.live_workers(), 0, "drop must join all workers");
    let out = submitter.join().expect("submitter thread");
    assert_eq!(out, (0..24).collect::<Vec<usize>>());
    assert_eq!(counter.load(Ordering::SeqCst), 24, "no job may be dropped on shutdown");
    // a handle outliving the pool still completes work (inline helping)
    let late: Vec<usize> =
        handle.run_all((0..5usize).map(|i| Box::new(move || i * i) as Job<'static, usize>).collect());
    assert_eq!(late, vec![0, 1, 4, 9, 16]);
}

#[test]
fn stress_10k_tiny_jobs_through_2_worker_pool() {
    // ISSUE 3 satellite: 10k tiny jobs, 2 workers — exactly-once,
    // submission order, no starvation
    let out: Vec<usize> = with_deadline(120, || {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Job<'static, usize>> = (0..10_000usize)
            .map(|i| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i.wrapping_mul(2654435761)
                }) as Job<'static, usize>
            })
            .collect();
        let out = pool.run_all(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 10_000);
        out
    });
    let expect: Vec<usize> = (0..10_000usize).map(|i| i.wrapping_mul(2654435761)).collect();
    assert_eq!(out, expect);
}

#[test]
fn sixty_four_shard_gram_stats_below_threshold_is_bitwise_native() {
    // ISSUE 3 satellite: m = 100 over 64 shards is far below any work
    // threshold — the adaptive fallback path must stay bit-identical to
    // NativeBackend (and the forced pool path must match it too)
    let mut rng = Rng::new(77);
    let m = 100usize;
    let ell = 5usize;
    let cols: Vec<Vec<f64>> =
        (0..ell).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
    let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let store = ColumnStore::from_cols(&cols, 64);
    assert_eq!(store.n_shards(), 64);
    let (atb_n, btb_n) = NativeBackend.gram_stats(&store, &b);

    let sharded = ShardedBackend::new(4);
    assert!(
        ell * (m / 64) < sharded.min_work_threshold(),
        "test must exercise the fallback path"
    );
    let (atb_s, btb_s) = sharded.gram_stats(&store, &b);
    assert_eq!(btb_n.to_bits(), btb_s.to_bits(), "fallback btb bits diverge");
    for (j, (a, s)) in atb_n.iter().zip(atb_s.iter()).enumerate() {
        assert_eq!(a.to_bits(), s.to_bits(), "fallback atb[{j}] bits diverge");
    }

    let forced = ShardedBackend::new(4).with_min_work(0);
    let (atb_f, btb_f) = forced.gram_stats(&store, &b);
    assert_eq!(btb_n.to_bits(), btb_f.to_bits(), "forced-parallel btb bits diverge");
    for (j, (a, s)) in atb_n.iter().zip(atb_f.iter()).enumerate() {
        assert_eq!(a.to_bits(), s.to_bits(), "forced-parallel atb[{j}] bits diverge");
    }
}

#[test]
fn map_through_handle_preserves_order_under_contention() {
    let pool = ThreadPool::new(4);
    let handle = pool.handle();
    let items: Vec<usize> = (0..2000).collect();
    // two threads map concurrently over the same pool
    let h2 = handle.clone();
    let items2 = items.clone();
    let t = std::thread::spawn(move || h2.map(&items2, |&i| i + 1));
    let a = handle.map(&items, |&i| i * 2);
    let b = t.join().expect("mapper thread");
    assert_eq!(a, items.iter().map(|&i| i * 2).collect::<Vec<usize>>());
    assert_eq!(b, items.iter().map(|&i| i + 1).collect::<Vec<usize>>());
}

//! Serving control-plane integration: registry → router → service →
//! backend, across module boundaries.
//!
//! Pins the PR-4 acceptance contracts: a persisted pipeline served
//! through the registry transforms **bit-identically** to the in-memory
//! one on both native and sharded backends; weighted A/B replies always
//! come from the arm that was assigned (correct-model, verified through
//! scores); hot swap mid-traffic never drops or double-answers a
//! request; and the `RouterReport` totals account for every submission.

use std::sync::Arc;

use avi_scale::coordinator::registry::ModelRegistry;
use avi_scale::coordinator::router::ModelRouter;
use avi_scale::coordinator::service::{ServeConfig, ServeRequest, TransformService};
use avi_scale::data::synthetic::synthetic_dataset;
use avi_scale::estimator::{persist, EstimatorConfig};
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::{train_pipeline, PipelineConfig, PipelineModel};
use avi_scale::svm::linear::LinearSvmConfig;

fn trained(method: &str, psi: f64, seed: u64) -> Arc<PipelineModel> {
    let ds = synthetic_dataset(300, seed);
    let cfg = PipelineConfig {
        estimator: EstimatorConfig::parse(method, psi).unwrap(),
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    };
    Arc::new(train_pipeline(&cfg, &ds).unwrap())
}

fn score_bits(svc: &TransformService, rows: &[Vec<f64>]) -> Vec<Vec<u64>> {
    let reply = svc.submit(ServeRequest::batch(rows.to_vec()));
    reply
        .answer()
        .unwrap()
        .predictions
        .iter()
        .map(|p| p.scores.iter().map(|s| s.to_bits()).collect())
        .collect()
}

#[test]
fn registry_roundtrip_serves_bit_identically_on_both_backends() {
    let dir = std::env::temp_dir().join("avi_scale_serve_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ds = synthetic_dataset(64, 31);
    let rows: Vec<Vec<f64>> = (0..64).map(|i| ds.x.row(i).to_vec()).collect();
    for method in ["cgavi-ihb", "vca"] {
        let in_memory = trained(method, 0.01, 1);
        let path = dir.join(format!("{method}.json"));
        persist::save(&in_memory, &path).unwrap();
        let mut registry = ModelRegistry::new();
        let loaded = registry.load_path("m", "v1", &path).unwrap();
        for cfg in [ServeConfig::new().native(), ServeConfig::new().sharded(3)] {
            let svc_mem = TransformService::start(in_memory.clone(), cfg.clone());
            let svc_reg = TransformService::start(loaded.clone(), cfg.clone());
            let a = score_bits(&svc_mem, &rows);
            let b = score_bits(&svc_reg, &rows);
            assert_eq!(a, b, "{method}/{:?}: save→load→serve drifted bitwise", cfg.backend);
            svc_mem.shutdown();
            svc_reg.shutdown();
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_to_router_end_to_end() {
    let dir = std::env::temp_dir().join("avi_scale_serve_manifest");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    persist::save(&trained("cgavi-ihb", 0.01, 2), &dir.join("a.json")).unwrap();
    persist::save(&trained("abm", 0.01, 2), &dir.join("b.json")).unwrap();
    let manifest = ModelRegistry::manifest_json(&[
        ("m".into(), "v1".into(), "a.json".into()),
        ("m".into(), "v2".into(), "b.json".into()),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();

    let mut registry = ModelRegistry::new();
    registry.load_manifest(&dir.join("manifest.json")).unwrap();
    // latest (v2) serves by default; an A/B split reaches both
    let router = ModelRouter::from_registry(&registry, &ServeConfig::default());
    let ds = synthetic_dataset(8, 3);
    let ans = router.predict("m", ds.x.row(0).to_vec()).unwrap();
    assert_eq!(ans.model_version, "v2");
    router
        .register_ab(
            &registry,
            "m",
            &[("v1".into(), 50), ("v2".into(), 50)],
            7,
            &ServeConfig::default(),
        )
        .unwrap();
    let versions: Vec<String> = (0..16)
        .map(|i| router.predict("m", ds.x.row(i % 8).to_vec()).unwrap().model_version)
        .collect();
    assert!(versions.iter().any(|v| v == "v1"));
    assert!(versions.iter().any(|v| v == "v2"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ab_replies_come_from_the_assigned_arm_with_its_own_scores() {
    // correct-model invariant, strengthened: the reply's scores must be
    // the serving version's own decision values for that row
    let v1 = trained("cgavi-ihb", 0.001, 4);
    let v2 = trained("cgavi-ihb", 0.05, 5);
    let router = ModelRouter::new();
    router
        .register_split(
            "m",
            vec![("v1".into(), v1.clone(), 50), ("v2".into(), v2.clone(), 50)],
            11,
            &ServeConfig::default(),
        )
        .unwrap();
    let ds = synthetic_dataset(60, 6);
    let native = avi_scale::backend::NativeBackend;
    let (l1, s1) = v1.predict_scores_with_backend(&ds.x, &native);
    let (l2, s2) = v2.predict_scores_with_backend(&ds.x, &native);
    let mut seen = [0usize; 2];
    for i in 0..60 {
        let ans = router.predict("m", ds.x.row(i).to_vec()).unwrap();
        let (labels, scores) = match ans.model_version.as_str() {
            "v1" => (&l1, &s1),
            "v2" => (&l2, &s2),
            other => panic!("unknown version {other}"),
        };
        assert_eq!(ans.label(), labels[i], "row {i} label from wrong model");
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(&ans.predictions[0].scores),
            bits(&scores[i]),
            "row {i} scores from wrong model"
        );
        seen[usize::from(ans.model_version == "v2")] += 1;
    }
    assert!(seen[0] > 0 && seen[1] > 0, "50/50 split never reached one arm: {seen:?}");
    let report = router.report();
    assert_eq!(report.total_requests, 60);
    assert_eq!(report.total_rejected, 0);
}

#[test]
fn hot_swap_mid_traffic_keeps_exactly_once_fifo_and_old_version_replies() {
    // one model trained twice identically: labels are version-agnostic,
    // so FIFO/correctness checks survive the swap boundary
    let model = trained("cgavi-ihb", 0.01, 7);
    let ds = synthetic_dataset(64, 8);
    let offline = model.predict(&ds.x);
    let router = Arc::new(ModelRouter::new());
    router.register("m", "v1", model.clone(), ServeConfig::default());

    let total = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        // four clients hammer the route with sequential (FIFO) requests
        for t in 0..4usize {
            let router = router.clone();
            let ds = &ds;
            let offline = &offline;
            let total = &total;
            scope.spawn(move || {
                for i in 0..32usize {
                    let row = (t * 16 + i) % 64;
                    let ans = router.predict("m", ds.x.row(row).to_vec()).unwrap();
                    assert_eq!(ans.model_key, "m");
                    assert_eq!(
                        ans.label(),
                        offline[row],
                        "client {t} request {i} served wrong"
                    );
                    total.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }
            });
        }
        // meanwhile: three hot swaps and a rollback
        let router2 = router.clone();
        let model = model.clone();
        scope.spawn(move || {
            for (_, version) in (0..4usize).zip(["v2", "v3", "v4", "v1"]) {
                std::thread::sleep(std::time::Duration::from_millis(3));
                router2.register("m", version, model.clone(), ServeConfig::default());
            }
        });
    });
    assert_eq!(total.load(std::sync::atomic::Ordering::SeqCst), 128);
    // every submission is accounted for across live + retired arms
    let report = router.report();
    assert_eq!(report.total_requests, 128, "report lost traffic across swaps:\n{:#?}", report.routes);
    assert_eq!(report.total_rejected, 0);
    // the report still parses as one JSON document
    let json = report.to_json();
    assert!(json.contains("\"total_requests\": 128"), "{json}");
}

#[test]
fn fifo_holds_within_one_key_across_a_swap() {
    let model = trained("cgavi-ihb", 0.01, 9);
    let ds = synthetic_dataset(40, 10);
    let offline = model.predict(&ds.x);
    let router = ModelRouter::new();
    router.register("m", "v1", model.clone(), ServeConfig::default());
    // enqueue 40 ordered requests, swapping the route half-way through
    let mut pendings = Vec::new();
    for i in 0..40 {
        if i == 20 {
            router.register("m", "v2", model.clone(), ServeConfig::default());
        }
        pendings.push(router.enqueue("m", ServeRequest::row(ds.x.row(i).to_vec())).unwrap());
    }
    let answers: Vec<_> = pendings.into_iter().map(|p| p.wait().answer().unwrap()).collect();
    // in-order, exactly once, each served by the generation that admitted it
    for (i, ans) in answers.iter().enumerate() {
        assert_eq!(ans.label(), offline[i], "answer {i} out of order or wrong");
        let expect = if i < 20 { "v1" } else { "v2" };
        assert_eq!(ans.model_version, expect, "answer {i} wrong generation");
    }
    assert_eq!(router.report().total_requests, 40);
}

//! End-to-end pipeline integration: Algorithm 2 on the paper's datasets
//! (scaled down), across all estimators, including CV grid search and
//! the serving path — the cross-module composition tests.

use std::sync::Arc;

use avi_scale::coordinator::pool::ThreadPool;
use avi_scale::coordinator::service::{ServeConfig, TransformService};
use avi_scale::data::splits::train_test_split;
use avi_scale::data::{load_registry_dataset, synthetic::synthetic_dataset};
use avi_scale::estimator::EstimatorConfig;
use avi_scale::oavi::OaviConfig;
use avi_scale::ordering::FeatureOrdering;
use avi_scale::pipeline::gridsearch::grid_search;
use avi_scale::pipeline::report::{run_cell, Method, Protocol};
use avi_scale::pipeline::{train_pipeline, PipelineConfig};
use avi_scale::svm::linear::LinearSvmConfig;

fn default_cfg(estimator: EstimatorConfig) -> PipelineConfig {
    PipelineConfig {
        estimator,
        svm: LinearSvmConfig::default(),
        ordering: FeatureOrdering::Pearson,
    }
}

#[test]
fn synthetic_separates_well_with_cgavi_ihb() {
    // the paper's headline qualitative claim on its own synthetic set:
    // OAVI features make the two varieties (nearly) linearly separable
    let ds = synthetic_dataset(3000, 1);
    let split = train_test_split(&ds, 0.6, 0);
    let model = train_pipeline(
        &default_cfg(EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.005))),
        &split.train,
    )
    .unwrap();
    let err = model.error_on(&split.test);
    assert!(err < 0.12, "synthetic test error {err}");
}

#[test]
fn every_registry_dataset_trains_every_estimator() {
    for name in ["bank", "htru", "seeds", "spam"] {
        let ds = load_registry_dataset(name, 0.04, 7).unwrap();
        let split = train_test_split(&ds, 0.6, 1);
        for estimator in EstimatorConfig::battery(0.01) {
            let model = train_pipeline(&default_cfg(estimator), &split.train)
                .unwrap_or_else(|e| panic!("{name}/{}: {e}", estimator.name()));
            let err = model.error_on(&split.test);
            assert!(
                err <= 0.55,
                "{name}/{}: error {err} worse than chance",
                estimator.name()
            );
        }
    }
}

#[test]
fn grid_search_plus_refit_beats_worst_grid_point() {
    let ds = load_registry_dataset("bank", 0.25, 3).unwrap();
    let split = train_test_split(&ds, 0.6, 2);
    let pool = ThreadPool::new(2);
    let estimator = EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01));
    let gs = grid_search(
        std::slice::from_ref(&estimator),
        FeatureOrdering::Pearson,
        &split.train,
        &[0.05, 0.005],
        &[1e-2, 1e-4],
        3,
        5,
        &pool,
    )
    .unwrap();
    let worst = gs.table.iter().map(|t| t.cv_error).fold(0.0f64, f64::max);
    assert!(gs.best_cv_error <= worst);
    assert_eq!(gs.best_name, "CGAVI-IHB");
    // refit with the winner generalizes (the winning config carries ψ)
    let cfg = PipelineConfig {
        estimator: gs.best,
        svm: LinearSvmConfig { lambda: gs.best_lambda, ..Default::default() },
        ordering: FeatureOrdering::Pearson,
    };
    let model = train_pipeline(&cfg, &split.train).unwrap();
    assert!(model.error_on(&split.test) < 0.2, "bank should be near-separable");
}

#[test]
fn mixed_method_grid_search_selects_one_winner_on_registry_data() {
    // the estimator layer's payoff: one CV search racing OAVI, ABM, and
    // VCA on the same folds, winner reported through FitReport.name()
    let ds = load_registry_dataset("seeds", 1.0, 21).unwrap();
    let split = train_test_split(&ds, 0.6, 6);
    let pool = ThreadPool::new(2);
    let battery = EstimatorConfig::battery(0.01);
    let gs = grid_search(
        &battery,
        FeatureOrdering::Pearson,
        &split.train,
        &[0.01],
        &[1e-3],
        2,
        13,
        &pool,
    )
    .unwrap();
    assert_eq!(gs.table.len(), battery.len());
    let names: Vec<String> = battery.iter().map(|c| c.name()).collect();
    assert!(names.contains(&gs.best_name), "winner {}", gs.best_name);
    // the winning config refits end-to-end
    let model = train_pipeline(
        &PipelineConfig {
            estimator: gs.best,
            svm: LinearSvmConfig { lambda: gs.best_lambda, ..Default::default() },
            ordering: FeatureOrdering::Pearson,
        },
        &split.train,
    )
    .unwrap();
    assert_eq!(model.transformer.method_name, gs.best_name);
    assert!(model.error_on(&split.test) <= 0.5);
}

#[test]
fn table3_cell_protocol_runs_reduced() {
    let ds = load_registry_dataset("seeds", 1.0, 11).unwrap();
    let protocol = Protocol {
        n_splits: 2,
        cv_folds: 2,
        psis: &[0.01],
        lambdas: &[1e-3],
        ..Default::default()
    };
    let pool = ThreadPool::new(2);
    let cell = run_cell(
        Method::Estimator(EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))),
        &ds,
        &protocol,
        &pool,
    )
    .unwrap();
    assert!(cell.error_mean < 0.4, "seeds error {}", cell.error_mean);
    assert!(cell.size > 0.0);
}

#[test]
fn serving_path_agrees_with_batch_path_on_registry_data() {
    let ds = load_registry_dataset("htru", 0.03, 13).unwrap();
    let split = train_test_split(&ds, 0.6, 3);
    let model = Arc::new(
        train_pipeline(
            &default_cfg(EstimatorConfig::Oavi(OaviConfig::cgavi_ihb(0.01))),
            &split.train,
        )
        .unwrap(),
    );
    let offline = model.predict(&split.test.x);
    let svc = TransformService::start(model.clone(), ServeConfig::default());
    let rows: Vec<Vec<f64>> =
        (0..split.test.len()).map(|i| split.test.x.row(i).to_vec()).collect();
    let online: Vec<usize> =
        svc.predict_many(rows).unwrap().into_iter().map(|r| r.label()).collect();
    assert_eq!(online, offline);
    svc.shutdown();
}

#[test]
fn out_sample_vanishing_on_registry_data() {
    // paper §1.1/§3.3: CGAVI generators vanish on out-sample data too
    let ds = load_registry_dataset("bank", 0.3, 17).unwrap();
    let split = train_test_split(&ds, 0.6, 4);
    let psi = 0.01;
    for k in 0..ds.n_classes {
        let x_train = split.train.class_matrix(k);
        let x_test = split.test.class_matrix(k);
        let model = avi_scale::oavi::Oavi::new(OaviConfig::cgavi_ihb(psi))
            .fit(&x_train)
            .unwrap();
        let gs = model.generator_set();
        for (gi, mse) in gs.mse_on(&x_test).iter().enumerate() {
            assert!(
                *mse < 20.0 * psi,
                "class {k} generator {gi}: out-sample MSE {mse} ≫ ψ={psi}"
            );
        }
    }
}

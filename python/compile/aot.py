"""AOT-lower the L2 model to HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per (function, shape) in DESIGN.md §6 plus a
``manifest.json`` the Rust runtime uses to discover artifacts and their
shapes.  Pure build-time tooling — never imported at runtime.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Fixed shapes (DESIGN.md §6). M_TILE rows per dispatch; Rust accumulates
# across tiles.  L_PAD sizes cover the live ℓ range; G_PAD generators.
M_TILE = 4096
L_PADS = (64, 256)
G_PAD = 256


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs():
    """(name, fn, example_args) for every artifact we ship."""
    specs = []
    for l_pad in L_PADS:
        specs.append(
            (
                f"gram_update_{M_TILE}x{l_pad}",
                model.gram_update_aot,
                (f32(M_TILE, l_pad), f32(M_TILE)),
            )
        )
        specs.append(
            (
                f"oracle_solve_{l_pad}",
                model.oracle_solve_aot,
                (f32(l_pad, l_pad), f32(l_pad), f32(), f32(l_pad)),
            )
        )
        specs.append(
            (
                f"ihb_update_{l_pad}",
                model.ihb_update_aot,
                (f32(l_pad, l_pad), f32(l_pad), f32(), f32(l_pad), f32(l_pad)),
            )
        )
        specs.append(
            (
                f"transform_{M_TILE}x{l_pad}x{G_PAD}",
                model.transform_aot,
                (f32(M_TILE, l_pad), f32(l_pad, G_PAD), f32(M_TILE, G_PAD)),
            )
        )
    return specs


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="substring filter on artifact names"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "m_tile": M_TILE,
        "l_pads": list(L_PADS),
        "g_pad": G_PAD,
        "artifacts": {},
    }
    for name, fn, example_args in artifact_specs():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [list(a.shape) for a in example_args],
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()

"""L2 — JAX compute graph for the OAVI oracle, calling the L1 kernels.

Four fixed-shape jitted functions make up the AOT surface consumed by the
Rust runtime (see DESIGN.md §6 for the artifact contract):

- ``gram_update``    : per-border-term column statistics over a row tile
                       (calls the Pallas gram kernel).  Rust streams row
                       tiles and accumulates partial sums ⇒ linear in m.
- ``oracle_solve``   : IHB closed-form coefficients c = −N·A^Tb and the
                       optimal residual m·MSE = b^Tb + c^T A^Tb.
- ``ihb_update``     : Theorem 4.9 block-inverse append for the maintained
                       N = (A^T A)^{-1} when a border term joins O.
- ``transform``      : the (FT) feature map |A·C + U| (calls the Pallas
                       transform kernel).

Dead padding is handled with 0/1 masks so one artifact serves every live
size ℓ ≤ L_PAD.  All functions are pure and shape-static, which is what
lets ``aot.py`` lower them once to HLO text.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.gram import gram_update as _gram_kernel
from compile.kernels.rank1 import rank1_update as _rank1_kernel
from compile.kernels.transform import transform as _transform_kernel


def gram_update(a, b):
    """Partial (A^T b, b^T b) over one (M_TILE, L_PAD) row tile.

    Thin L2 wrapper over the L1 Pallas kernel; kept separate so the AOT
    artifact boundary is a jax function, not a pallas_call.
    """
    return _gram_kernel(a, b)


def oracle_solve(n_inv, atb, btb, mask):
    """Closed-form IHB solution of Line 7 / (CCOP) warm start.

    Args:
      n_inv: (L, L) f32 — maintained (A^T A)^{-1}, garbage outside the live
        block (the mask zeroes it out).
      atb:   (L,) f32 — accumulated A^T b (live prefix, zero-padded).
      btb:   ()  f32 — accumulated b^T b.
      mask:  (L,) f32 — 1.0 on live coordinates, 0.0 on padding.

    Returns:
      c:     (L,) f32 — optimal coefficients −(A^TA)^{-1}A^Tb (0 on padding).
      mse_m: ()  f32 — m·MSE(g, X) at the optimum: b^Tb + c^T A^Tb.
    """
    atb_l = atb * mask
    c = -(jnp.dot(n_inv, atb_l)) * mask
    mse_m = btb + jnp.dot(c, atb_l)
    return c, mse_m


def ihb_update(n_inv, atb, btb, mask, k):
    """Theorem 4.9: (A^TA)^{-1} → ((A,b)^T(A,b))^{-1} in O(ℓ²).

    ``k`` is the index of the appended column (one-hot encoded as an (L,)
    f32 vector by the Rust caller so the artifact stays shape-static);
    ``mask`` selects the previously-live block and must have mask·k == 0.

    Returns the updated padded inverse.  Requires the Schur complement
    s = b^Tb − b^TA N A^Tb > 0 (columns independent — guaranteed by OAVI's
    construction; the Rust caller guards and falls back to a Cholesky
    rebuild otherwise).
    """
    ek = k  # one-hot (L,)
    atb_l = atb * mask
    w = jnp.dot(n_inv, atb_l) * mask       # N A^T b
    s = btb - jnp.dot(atb_l, w)            # Schur complement
    inv_s = 1.0 / s
    # two fused masked rank-1 passes (L1 Pallas kernel):
    #   n1  = N ⊙ (mask maskᵀ) + (1/s)·w wᵀ
    #   out = n1 ⊙ (1 1ᵀ)      + (1)·(e_k + w·(−1/s))(…)ᵀ …
    # the border row/col and corner assemble from e_k and n2 = −w/s:
    n1 = _rank1_kernel(n_inv, w, w, mask, mask, inv_s)
    n2_plus_corner = ek * (0.5 * inv_s) - w * inv_s  # shared by row and col
    ones = jnp.ones_like(mask)
    out = _rank1_kernel(n1, ek, n2_plus_corner, ones, ones, jnp.float32(1.0))
    out = _rank1_kernel(out, n2_plus_corner, ek, ones, ones, jnp.float32(1.0))
    return out


def transform(a, c, u):
    """(FT) feature map over one row tile: |A·C + U| (Pallas kernel)."""
    return _transform_kernel(a, c, u)


# --- AOT entry points (return tuples — required by the HLO text bridge) ---

def gram_update_aot(a, b):
    atb, btb = gram_update(a, b)
    return (atb, btb)


def oracle_solve_aot(n_inv, atb, btb, mask):
    c, mse_m = oracle_solve(n_inv, atb, btb, mask)
    return (c, mse_m)


def ihb_update_aot(n_inv, atb, btb, mask, k):
    return (ihb_update(n_inv, atb, btb, mask, k),)


def transform_aot(a, c, u):
    return (transform(a, c, u),)

"""L1 Pallas kernel: fused symmetric rank-1 update — the O(ℓ²) core of
the Theorem 4.9 inverse append.

``ihb_update`` spends its FLOPs in two places: the mat-vec ``w = N·Aᵀb``
and the rank-1 correction ``N + w wᵀ / s``.  This kernel fuses the rank-1
correction with the masking so the (L, L) intermediate is produced in one
VMEM-resident pass:

    out = a * outer(row_mask, col_mask) + alpha * outer(u, v)

TPU mapping: one (L_BLOCK, L_BLOCK) tile per grid step; u/v slabs are
broadcast along the tile rows/cols — pure VPU work (no MXU needed), bound
by the VMEM write bandwidth of `out`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

L_BLOCK = 128


def _rank1_kernel(a_ref, u_ref, v_ref, rm_ref, cm_ref, alpha_ref, out_ref):
    """out = a ⊙ (rm cmᵀ) + alpha · (u vᵀ) for one (BL, BL) tile."""
    u = u_ref[...]          # (BL, 1)
    v = v_ref[...]          # (1, BL)
    rm = rm_ref[...]        # (BL, 1)
    cm = cm_ref[...]        # (1, BL)
    alpha = alpha_ref[0, 0]
    out_ref[...] = a_ref[...] * (rm * cm) + alpha * (u * v)


@functools.partial(jax.jit, static_argnames=())
def rank1_update(a, u, v, row_mask, col_mask, alpha):
    """Masked rank-1 update over a padded square matrix.

    Args:
      a:        (L, L) f32.
      u:        (L,)   f32 — left vector.
      v:        (L,)   f32 — right vector.
      row_mask: (L,)   f32 — 0/1 rows of `a` to keep.
      col_mask: (L,)   f32 — 0/1 cols of `a` to keep.
      alpha:    ()     f32 — scale of the outer product.

    Returns:
      (L, L) f32: ``a·(row_mask col_maskᵀ) + alpha·(u vᵀ)``.
    """
    l_pad = a.shape[0]
    block = min(L_BLOCK, l_pad)
    assert l_pad % block == 0, (l_pad, block)
    grid = (l_pad // block, l_pad // block)
    return pl.pallas_call(
        _rank1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block), lambda i, j: (0, j)),
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l_pad, l_pad), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        a,
        u.reshape(l_pad, 1),
        v.reshape(1, l_pad),
        row_mask.reshape(l_pad, 1),
        col_mask.reshape(1, l_pad),
        alpha.reshape(1, 1),
    )

"""L1 Pallas kernel: masked, tiled Gram update — the O(m·ℓ) hot spot of OAVI.

For every border term u, OAVI (with IHB, Theorem 4.9) needs exactly two
sample-dependent quantities: ``A^T b`` and ``b^T b`` where ``A = O(X)`` is the
evaluation matrix of the non-leading terms and ``b = u(X)`` is the evaluation
vector of the candidate leading term.  Everything else in the oracle is
O(ℓ²) work on the (inverse) Gram matrix.  This kernel computes the partial
``A^T b`` / ``b^T b`` over one (M_TILE × L_PAD) row tile; the Rust runtime
streams row tiles and accumulates, so the end-to-end cost is linear in m
(the paper's Theorem 4.3 headline) with a fixed-shape AOT artifact.

TPU mapping (DESIGN.md §Hardware-Adaptation): the row tile lives in VMEM
(4096×256 f32 = 4 MiB); the reduction is expressed as a matmul
``A^T @ b[:, None]`` so the MXU performs it; the grid walks the L dimension
in 128-wide MXU-aligned blocks.  Under ``interpret=True`` the same kernel
lowers to plain HLO so the CPU PJRT client can execute it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned block width for the L (feature/term) dimension.
L_BLOCK = 128


def _gram_update_kernel(a_ref, b_ref, atb_ref, btb_ref):
    """One grid step: partial A^T b for an L_BLOCK-wide column slab.

    a_ref:   (M_TILE, L_BLOCK) slab of the evaluation matrix A = O(X)
    b_ref:   (M_TILE, 1)       candidate column b = u(X)
    atb_ref: (L_BLOCK, 1)      output slab of A^T b
    btb_ref: (1, 1)            output b^T b (written once, by program 0)
    """
    a = a_ref[...]
    b = b_ref[...]
    # (L_BLOCK, M) @ (M, 1) -> (L_BLOCK, 1): contraction over samples on
    # the MXU. f32 accumulation.
    atb_ref[...] = jnp.dot(
        a.T, b, preferred_element_type=jnp.float32
    )
    # b^T b is identical for every grid step; write it on the first.
    @pl.when(pl.program_id(0) == 0)
    def _():
        btb_ref[...] = jnp.dot(
            b.T, b, preferred_element_type=jnp.float32
        )


@functools.partial(jax.jit, static_argnames=())
def gram_update(a, b):
    """Partial Gram update over one row tile.

    Args:
      a: (M_TILE, L_PAD) float32 — row tile of A (dead columns zero-padded).
      b: (M_TILE,)       float32 — row tile of the candidate column.

    Returns:
      (atb, btb): (L_PAD,) float32 partial ``A^T b`` and () float32 partial
      ``b^T b``; partial sums over this tile only — the caller accumulates.
    """
    m_tile, l_pad = a.shape
    # Narrow artifacts (L_PAD < 128) use a single full-width block; wide
    # ones walk MXU-aligned 128-lane slabs.
    block = min(L_BLOCK, l_pad)
    assert l_pad % block == 0, (l_pad, block)
    b2 = b.reshape(m_tile, 1)
    grid = (l_pad // block,)
    atb, btb = pl.pallas_call(
        _gram_update_kernel,
        grid=grid,
        in_specs=[
            # Walk A in L_BLOCK-wide column slabs; full M rows per step.
            pl.BlockSpec((m_tile, block), lambda i: (0, i)),
            pl.BlockSpec((m_tile, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b2)
    return atb.reshape(l_pad), btb.reshape(())

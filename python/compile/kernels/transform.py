"""L1 Pallas kernel: fused ``|A @ C + U|`` — the (FT) feature transform.

Test-time (Theorem 4.2) evaluation of a generator set G over a data tile:
``A = O(X)`` holds the evaluations of the non-leading terms, ``C`` the
coefficient matrix (one column per generator), and ``U`` the evaluations of
the leading terms (LTC = 1).  The transformed features are the absolute
generator values |g(x)| = |O(x)·c_g + u_g(x)| per Algorithm 2 / (FT).

The matmul, the bias add, and the absolute value are fused in one kernel so
the (M, G) intermediate never round-trips to HBM.  Grid walks (M, G) in
MXU-aligned blocks with the full K (=L_PAD) contraction per step — for
L_PAD ≤ 256 the K slab fits VMEM comfortably (DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

M_BLOCK = 512
G_BLOCK = 128


def _transform_kernel(a_ref, c_ref, u_ref, out_ref):
    """out = |a @ c + u| for one (M_BLOCK, G_BLOCK) output tile."""
    acc = jnp.dot(
        a_ref[...], c_ref[...], preferred_element_type=jnp.float32
    )
    out_ref[...] = jnp.abs(acc + u_ref[...])


@functools.partial(jax.jit, static_argnames=())
def transform(a, c, u):
    """Fused feature transform over one row tile.

    Args:
      a: (M_TILE, L_PAD) float32 — evaluations of O over the tile.
      c: (L_PAD, G_PAD)  float32 — generator coefficient matrix
         (dead rows/columns zero-padded).
      u: (M_TILE, G_PAD) float32 — leading-term evaluations.

    Returns:
      (M_TILE, G_PAD) float32 — |a @ c + u|.
    """
    m_tile, l_pad = a.shape
    _, g_pad = c.shape
    assert m_tile % M_BLOCK == 0 and g_pad % G_BLOCK == 0
    grid = (m_tile // M_BLOCK, g_pad // G_BLOCK)
    return pl.pallas_call(
        _transform_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((M_BLOCK, l_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((l_pad, G_BLOCK), lambda i, j: (0, j)),
            pl.BlockSpec((M_BLOCK, G_BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((M_BLOCK, G_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_tile, g_pad), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, c, u)

"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel must match its
reference under ``assert_allclose`` across the hypothesis shape/dtype sweep
in ``python/tests/``.  They are also what the L2 model would be without the
kernels, which makes them the "roofline" comparator for DESIGN.md §Perf.
"""

from __future__ import annotations

import jax.numpy as jnp


def gram_update_ref(a, b):
    """Reference for kernels.gram.gram_update: (A^T b, b^T b)."""
    atb = a.T @ b
    btb = jnp.dot(b, b)
    return atb.astype(jnp.float32), btb.astype(jnp.float32)


def transform_ref(a, c, u):
    """Reference for kernels.transform.transform: |A @ C + U|."""
    return jnp.abs(a @ c + u).astype(jnp.float32)


def oracle_solve_ref(n_inv, atb, btb, mask):
    """Reference for model.oracle_solve.

    c = -(A^T A)^{-1} A^T b restricted to live coordinates; residual
    m·MSE = b^T b + c^T A^T b (valid at the optimum).
    """
    c = -(n_inv @ (atb * mask)) * mask
    mse_m = btb + jnp.dot(c, atb)
    return c.astype(jnp.float32), mse_m.astype(jnp.float32)


def ihb_update_ref(n_inv, atb, btb, mask, k):
    """Reference for model.ihb_update (Theorem 4.9 block-inverse append).

    Given N = (A^T A)^{-1} on the live block selected by ``mask`` (with
    mask[k] == 0 — index k is the appended column), returns the inverse of
    the bordered Gram matrix embedded in the same padded shape.
    """
    atb_l = atb * mask
    w = n_inv @ atb_l                      # N A^T b
    s = btb - jnp.dot(atb_l, w)            # Schur complement
    n1 = n_inv + jnp.outer(w, w) / s
    n2 = -w / s
    ek = jnp.zeros_like(atb).at[k].set(1.0)
    out = (
        n1 * jnp.outer(mask, mask)
        + jnp.outer(ek, n2 * mask)
        + jnp.outer(n2 * mask, ek)
        + jnp.outer(ek, ek) / s
    )
    return out.astype(jnp.float32)

"""L1 correctness: Pallas kernels vs pure-jnp references.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is THE
core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gram import L_BLOCK, gram_update
from compile.kernels.transform import G_BLOCK, M_BLOCK, transform
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape, dtype=np.float32, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------- gram ---


@settings(max_examples=25, deadline=None)
@given(
    m_tiles=st.integers(min_value=1, max_value=4),
    l_blocks=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_gram_update_matches_ref(m_tiles, l_blocks, seed, scale):
    rng = np.random.default_rng(seed)
    m, l = 8 * m_tiles, L_BLOCK * l_blocks
    a = rand(rng, m, l, scale=scale)
    b = rand(rng, m, scale=scale)
    atb, btb = gram_update(a, b)
    atb_r, btb_r = ref.gram_update_ref(a, b)
    np.testing.assert_allclose(atb, atb_r, rtol=1e-5, atol=1e-5 * scale**2)
    np.testing.assert_allclose(btb, btb_r, rtol=1e-5, atol=1e-5 * scale**2)


def test_gram_update_zero_padding_is_inert():
    """Zero-padded columns must yield exactly zero in A^T b."""
    rng = np.random.default_rng(0)
    a = np.zeros((16, L_BLOCK), np.float32)
    a[:, :5] = rand(rng, 16, 5)
    b = rand(rng, 16)
    atb, _ = gram_update(a, b)
    assert np.all(np.asarray(atb)[5:] == 0.0)


def test_gram_update_accumulates_across_tiles():
    """Summing per-tile partials equals the full-matrix product."""
    rng = np.random.default_rng(1)
    m, l, tiles = 32, L_BLOCK, 4
    a = rand(rng, m * tiles, l)
    b = rand(rng, m * tiles)
    acc_atb = np.zeros(l, np.float32)
    acc_btb = np.float32(0.0)
    for t in range(tiles):
        atb, btb = gram_update(a[t * m : (t + 1) * m], b[t * m : (t + 1) * m])
        acc_atb += np.asarray(atb)
        acc_btb += np.asarray(btb)
    np.testing.assert_allclose(acc_atb, a.T @ b, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(acc_btb, b @ b, rtol=2e-5)


def test_gram_update_dtype_is_f32():
    rng = np.random.default_rng(2)
    atb, btb = gram_update(rand(rng, 8, L_BLOCK), rand(rng, 8))
    assert atb.dtype == jnp.float32 and btb.dtype == jnp.float32


def test_gram_update_rejects_unaligned_l():
    rng = np.random.default_rng(3)
    with pytest.raises(AssertionError):
        gram_update(rand(rng, 8, L_BLOCK + 1), rand(rng, 8))


# ----------------------------------------------------------- transform ---


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(min_value=1, max_value=2),
    gi=st.integers(min_value=1, max_value=2),
    l=st.sampled_from([16, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_transform_matches_ref(mi, gi, l, seed):
    rng = np.random.default_rng(seed)
    m, g = M_BLOCK * mi, G_BLOCK * gi
    a = rand(rng, m, l)
    c = rand(rng, l, g)
    u = rand(rng, m, g)
    out = transform(a, c, u)
    np.testing.assert_allclose(
        out, ref.transform_ref(a, c, u), rtol=1e-4, atol=1e-4
    )


def test_transform_output_nonnegative():
    rng = np.random.default_rng(7)
    out = transform(
        rand(rng, M_BLOCK, 64), rand(rng, 64, G_BLOCK), rand(rng, M_BLOCK, G_BLOCK)
    )
    assert np.all(np.asarray(out) >= 0.0)


def test_transform_identity_coeffs():
    """C = I, U = 0 ⇒ output = |A| (padding-free sanity case)."""
    rng = np.random.default_rng(8)
    a = rand(rng, M_BLOCK, G_BLOCK)
    c = np.eye(G_BLOCK, dtype=np.float32)
    u = np.zeros((M_BLOCK, G_BLOCK), np.float32)
    np.testing.assert_allclose(transform(a, c, u), np.abs(a), rtol=1e-6)


# ------------------------------------------------------------- rank1 ---

from compile.kernels.rank1 import rank1_update


@settings(max_examples=20, deadline=None)
@given(
    l=st.sampled_from([8, 64, 128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rank1_update_matches_numpy(l, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, l, l)
    u = rand(rng, l)
    v = rand(rng, l)
    rm = (rng.uniform(size=l) > 0.3).astype(np.float32)
    cm = (rng.uniform(size=l) > 0.3).astype(np.float32)
    alpha = np.float32(rng.standard_normal())
    out = rank1_update(a, u, v, rm, cm, alpha)
    expect = a * np.outer(rm, cm) + alpha * np.outer(u, v)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_rank1_identity_masks_are_noop_with_zero_alpha():
    rng = np.random.default_rng(3)
    a = rand(rng, 64, 64)
    ones = np.ones(64, np.float32)
    zero = np.float32(0.0)
    out = rank1_update(a, rand(rng, 64), rand(rng, 64), ones, ones, zero)
    np.testing.assert_allclose(out, a, rtol=1e-7)

"""AOT bridge: lowering works, HLO text parses, and — crucially — the
lowered computation executes on the CPU PJRT backend with correct numerics
(the same path the Rust runtime takes)."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


def lower_text(fn, *example_args):
    return aot.to_hlo_text(jax.jit(fn).lower(*example_args))


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_every_artifact_spec_lowers_to_hlo_text():
    for name, fn, example_args in aot.artifact_specs():
        text = lower_text(fn, *example_args)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_hlo_text_has_no_custom_calls():
    """interpret=True must fully inline the Pallas kernels — a Mosaic
    custom-call in the HLO would be unloadable by the CPU PJRT client."""
    for name, fn, example_args in aot.artifact_specs():
        text = lower_text(fn, *example_args)
        assert "custom-call" not in text, name


@pytest.mark.parametrize("l_pad", [64])
def test_hlo_text_parses_back_to_module(l_pad):
    """The text artifact must re-parse into an HloModule — the exact step
    the Rust runtime performs (`HloModuleProto::from_text_file`).  Full
    compile+execute of the text is covered by rust/tests/runtime_parity.
    """
    m = aot.M_TILE
    text = lower_text(model.gram_update_aot, f32(m, l_pad), f32(m))
    mod = xc._xla.hlo_module_from_text(text)
    assert mod.as_serialized_hlo_module_proto()  # non-empty proto


@pytest.mark.parametrize("l_pad", [64])
def test_lowered_module_executes_on_cpu_pjrt(l_pad):
    """Compile the lowered StableHLO on the CPU PJRT client and check
    numerics — proves the AOT module itself (with the inlined Pallas
    kernel) is executable outside of jax.jit tracing."""
    from jaxlib import _jax

    m = aot.M_TILE
    lowered = jax.jit(model.gram_update_aot).lower(f32(m, l_pad), f32(m))
    mlir_bytes = str(lowered.compiler_ir("stablehlo")).encode()
    client = xc.make_cpu_client()
    dl = _jax.DeviceList(tuple(client.devices()))
    exe = client.compile_and_load(mlir_bytes, dl)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((m, l_pad)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    out = exe.execute_sharded(
        [client.buffer_from_pyval(a), client.buffer_from_pyval(b)]
    )
    bufs = out.disassemble_into_single_device_arrays()
    atb = np.asarray(bufs[0][0])
    btb = np.asarray(bufs[1][0])
    np.testing.assert_allclose(atb.reshape(-1), a.T @ b, rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(btb.reshape(()), b @ b, rtol=2e-4)


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    out_dir = tmp_path / "artifacts"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out_dir),
            "--only",
            "oracle_solve_64",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out_dir / "manifest.json").read_text())
    assert "oracle_solve_64" in manifest["artifacts"]
    assert (out_dir / "oracle_solve_64.hlo.txt").exists()

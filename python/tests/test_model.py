"""L2 correctness: model functions vs numpy ground truth.

oracle_solve must match the normal-equations solution (numpy lstsq);
ihb_update must match a freshly inverted bordered Gram matrix — this is
the Theorem 4.9 parity check at the python layer (the Rust layer repeats
it against its own Cholesky).
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def padded_problem(rng, m, l_live, l_pad):
    """Random well-conditioned least-squares instance, zero-padded."""
    a_live = rng.standard_normal((m, l_live)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    a = np.zeros((m, l_pad), np.float32)
    a[:, :l_live] = a_live
    gram = a_live.T @ a_live + 1e-4 * np.eye(l_live, dtype=np.float32)
    n_inv = np.zeros((l_pad, l_pad), np.float32)
    n_inv[:l_live, :l_live] = np.linalg.inv(gram)
    atb = np.zeros(l_pad, np.float32)
    atb[:l_live] = a_live.T @ b
    mask = np.zeros(l_pad, np.float32)
    mask[:l_live] = 1.0
    return a_live, b, n_inv, atb, np.float32(b @ b), mask


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=20, max_value=200),
    l_live=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_oracle_solve_matches_normal_equations(m, l_live, seed):
    rng = np.random.default_rng(seed)
    l_pad = 64
    a_live, b, n_inv, atb, btb, mask = padded_problem(rng, m, l_live, l_pad)
    c, mse_m = model.oracle_solve(n_inv, atb, btb, mask)
    c = np.asarray(c)
    # numpy ground truth: minimize ||A y + b||² ⇒ y = -lstsq(A, b)
    y, *_ = np.linalg.lstsq(a_live, -b, rcond=None)
    np.testing.assert_allclose(c[:l_live], y, rtol=2e-2, atol=2e-3)
    assert np.all(c[l_live:] == 0.0)
    resid = a_live @ c[:l_live] + b
    np.testing.assert_allclose(
        float(mse_m), float(resid @ resid), rtol=2e-2, atol=2e-3
    )


def test_oracle_solve_padding_garbage_is_ignored():
    """Garbage in dead regions of N/Atb must not leak into the output."""
    rng = np.random.default_rng(5)
    l_pad = 64
    a_live, b, n_inv, atb, btb, mask = padded_problem(rng, 50, 6, l_pad)
    n_dirty = n_inv.copy()
    n_dirty[6:, :] = 999.0
    n_dirty[:, 6:] = 999.0
    atb_dirty = atb.copy()
    atb_dirty[6:] = -777.0
    c0, m0 = model.oracle_solve(n_inv, atb, btb, mask)
    c1, m1 = model.oracle_solve(n_dirty, atb_dirty, btb, mask)
    np.testing.assert_allclose(np.asarray(c0)[:6], np.asarray(c1)[:6], rtol=1e-6)
    np.testing.assert_allclose(float(m0), float(m1), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=30, max_value=150),
    l_live=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ihb_update_matches_fresh_inverse(m, l_live, seed):
    """Theorem 4.9: the O(ℓ²) block append equals inverting from scratch."""
    rng = np.random.default_rng(seed)
    l_pad = 64
    a_live = rng.standard_normal((m, l_live)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    gram = (a_live.T @ a_live).astype(np.float32)
    n_inv = np.zeros((l_pad, l_pad), np.float32)
    n_inv[:l_live, :l_live] = np.linalg.inv(
        gram + 1e-6 * np.eye(l_live, dtype=np.float32)
    )
    atb = np.zeros(l_pad, np.float32)
    atb[:l_live] = a_live.T @ b
    mask = np.zeros(l_pad, np.float32)
    mask[:l_live] = 1.0
    k_onehot = np.zeros(l_pad, np.float32)
    k_onehot[l_live] = 1.0

    out = np.asarray(
        model.ihb_update(n_inv, atb, np.float32(b @ b), mask, k_onehot)
    )
    a_new = np.concatenate([a_live, b[:, None]], axis=1)
    fresh = np.linalg.inv(
        (a_new.T @ a_new) + 1e-6 * np.eye(l_live + 1, dtype=np.float32)
    )
    live = l_live + 1
    np.testing.assert_allclose(out[:live, :live], fresh, rtol=5e-2, atol=5e-3)
    # dead region must stay zero
    assert np.all(out[live:, :] == 0.0) and np.all(out[:, live:] == 0.0)


def test_ihb_update_ref_agrees_with_model():
    rng = np.random.default_rng(11)
    l_pad = 64
    a_live, b, n_inv, atb, btb, mask = padded_problem(rng, 80, 9, l_pad)
    k_onehot = np.zeros(l_pad, np.float32)
    k_onehot[9] = 1.0
    out_model = np.asarray(model.ihb_update(n_inv, atb, btb, mask, k_onehot))
    out_ref = np.asarray(ref.ihb_update_ref(n_inv, atb, btb, mask, 9))
    np.testing.assert_allclose(out_model, out_ref, rtol=1e-4, atol=1e-5)


def test_gram_update_wrapper_reexports_kernel():
    rng = np.random.default_rng(12)
    a = rng.standard_normal((8, 128)).astype(np.float32)
    b = rng.standard_normal(8).astype(np.float32)
    atb, btb = model.gram_update(a, b)
    np.testing.assert_allclose(np.asarray(atb), a.T @ b, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(btb), float(b @ b), rtol=1e-5)

#!/usr/bin/env bash
# Tier-1 verification gate for the data plane (run from the repo root):
#   fmt --check, clippy (-D warnings on the new data-plane modules),
#   release build, full test suite.
#
# Clippy note: the seed predates a clippy pass, so warnings are denied
# only in the modules this gate owns (backend/, the scaling bench, the
# parity tests); everything else is reported but non-fatal to keep the
# gate actionable.  Tighten the allowlist as modules get cleaned up.
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy =="
CLIPPY_LOG=$(mktemp)
# pipefail makes this fail loudly if clippy itself can't run (missing
# component) or emits deny-level errors; warnings exit 0 and are gated
# by the span grep below
cargo clippy --release --all-targets 2>&1 | tee "$CLIPPY_LOG"
# every rustc diagnostic carries a "--> path:line:col" span line; match
# spans inside the strict modules regardless of header distance
STRICT_SPANS='^[[:space:]]*--> (src/backend/|benches/micro_backend_scaling|tests/runtime_parity)'
if grep -E "$STRICT_SPANS" "$CLIPPY_LOG" >/dev/null; then
  echo "FAIL: clippy findings in strict data-plane modules:"
  grep -E "$STRICT_SPANS" "$CLIPPY_LOG"
  exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "verify.sh: all gates passed"

#!/usr/bin/env bash
# Tier-1 verification gate (run from the repo root):
#   fmt --check, clippy (-D warnings on the modules this gate owns),
#   release build, full test suite, and a CLI smoke pass that exercises
#   every estimator by name on a tiny synthetic dataset.
#
# Clippy note: the seed predates a clippy pass, so warnings are denied
# only in the modules the gate owns (the data plane from PR 1, the
# estimator layer from PR 2, and their tests/benches); everything else is
# reported but non-fatal to keep the gate actionable.  Tighten the
# allowlist as modules get cleaned up.
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check (advisory) =="
# Advisory until a toolchain'd environment runs `cargo fmt` once and
# commits the result: the seed predates any rustfmt pass (this repo's
# build container has no cargo), so --check failures here may be
# seed-era formatting rather than regressions.  Flip to fatal after the
# first normalization commit.
if ! cargo fmt --check; then
  echo "WARN: rustfmt drift detected — run 'cargo fmt', commit, then make this gate fatal"
fi

echo "== cargo clippy =="
CLIPPY_LOG=$(mktemp)
# pipefail makes this fail loudly if clippy itself can't run (missing
# component) or emits deny-level errors; warnings exit 0 and are gated
# by the span grep below
cargo clippy --release --all-targets 2>&1 | tee "$CLIPPY_LOG"
# every rustc diagnostic carries a "--> path:line:col" span line; match
# spans inside the strict modules regardless of header distance
STRICT_SPANS='^[[:space:]]*--> (src/artifact/|src/backend/|src/estimator/|src/coordinator/|src/storage/|src/pipeline/plan|src/data/csvio|src/linalg/simd|benches/micro_backend_scaling|benches/micro_gram_panel|benches/micro_persist_codec|benches/serve_router|benches/serve_transform|tests/runtime_parity|tests/estimator_conformance|tests/kernel_parity|tests/pool_concurrency|tests/serve_control_plane|tests/storage_parity|tests/frontdoor_e2e|tests/transform_plan_parity)'
if grep -E "$STRICT_SPANS" "$CLIPPY_LOG" >/dev/null; then
  echo "FAIL: clippy findings in strict modules:"
  grep -E "$STRICT_SPANS" "$CLIPPY_LOG"
  exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== concurrency suite: serial + multi-thread schedules =="
# The pool/two-level tests are scheduling-sensitive; run them under two
# regimes so interleaving bugs reproduce: RUST_TEST_THREADS=1 keeps
# sibling tests from perturbing the pool's schedules (the
# thread-sanitizer-friendly profile), the default mode adds cross-test
# contention on the same cores.
RUST_TEST_THREADS=1 cargo test --release --test pool_concurrency -q
cargo test --release --test pool_concurrency -q
RUST_TEST_THREADS=1 cargo test --release --test runtime_parity -q two_level
cargo test --release --test runtime_parity -q two_level
RUST_TEST_THREADS=1 cargo test --release --test runtime_parity -q pooled_per_class
cargo test --release --test runtime_parity -q pooled_per_class
# panel parity (ISSUE 5): the degree-batched path must be bitwise equal
# to the legacy per-candidate path under both scheduling regimes
RUST_TEST_THREADS=1 cargo test --release --test runtime_parity -q panel
cargo test --release --test runtime_parity -q panel
# kernel parity (ISSUE 6): the row-tiled/wide-lane micro-kernels, the
# block-threshold override hook, and the lazy cross rows are bitwise
# contracts; the process-global threshold pin and the sharded reduction
# must hold under both scheduling regimes
RUST_TEST_THREADS=1 cargo test --release --test kernel_parity -q
cargo test --release --test kernel_parity -q
# storage parity (ISSUE 7): spill-backed fits must be bitwise equal to
# in-memory, the resident pool must honor its byte budget, and corrupt
# segments must be refused; serial mode keeps temp-dir IO quiet, the
# default mode adds cross-test disk/pool contention
RUST_TEST_THREADS=1 cargo test --release --test storage_parity -q
cargo test --release --test storage_parity -q

echo "== CLI smoke: every estimator by name =="
BIN=target/release/avi-scale
SMOKE="--dataset synthetic --scale 0.0005 --seed 7 --psi 0.01"
for method in cgavi-ihb bpcgavi-wihb abm vca; do
  echo "-- fit --method $method"
  "$BIN" fit $SMOKE --method "$method"
done
echo "-- fit --method abm --backend sharded --shards 4 (deprecated alias)"
"$BIN" fit $SMOKE --method abm --backend sharded --shards 4
echo "-- fit --backend sharded at panel-engaging scale (ISSUE 5 smoke)"
# scale 0.01 of the 2M synthetic set → ~10k rows/class: stores shard and
# the degree-batched panels drive the sharded gram_panel kernel; the
# panel counters printed by cmd_fit must be live
PANEL_OUT=$("$BIN" fit --dataset synthetic --scale 0.01 --seed 7 --psi 0.005 \
  --method cgavi-ihb --backend sharded --workers 4)
echo "$PANEL_OUT"
echo "$PANEL_OUT" | grep -q 'panels    = [1-9]' || {
  echo "FAIL: sharded panel smoke reported zero panel passes"
  exit 1
}
echo "-- fit --numerics fast (ISSUE 6 smoke: opt-in f32 path + error budget)"
FAST_OUT=$("$BIN" fit $SMOKE --method cgavi-ihb --numerics fast)
echo "$FAST_OUT"
# the fit report JSON must carry the fast-mode fields: the mode itself
# and the measured error budget the driver asserted at fit time
echo "$FAST_OUT" | grep -q '"numerics":"fast"' || {
  echo "FAIL: --numerics fast did not report numerics=fast in the fit report"
  exit 1
}
echo "$FAST_OUT" | grep -q '"fast_max_abs_err":' || {
  echo "FAIL: --numerics fast fit report is missing the error budget fields"
  exit 1
}
# and exact mode must stay the default
"$BIN" fit $SMOKE --method cgavi-ihb | grep -q '"numerics":"exact"' || {
  echo "FAIL: default fit no longer reports numerics=exact"
  exit 1
}
echo "-- fit --method abm --workers 4 (two-level pool)"
"$BIN" fit $SMOKE --method abm --workers 4
echo "-- pipeline --method cgavi-ihb --workers 3"
"$BIN" pipeline $SMOKE --method cgavi-ihb --workers 3
echo "-- pipeline save/load round-trip (unified envelope, VCA included)"
SMOKE_DIR=$(mktemp -d)
for method in cgavi-ihb vca; do
  "$BIN" pipeline $SMOKE --method "$method" --save "$SMOKE_DIR/$method.json"
  "$BIN" predict $SMOKE --model "$SMOKE_DIR/$method.json"
done

echo "-- serve control plane: A/B split over two saved pipelines + shadow"
"$BIN" pipeline $SMOKE --method cgavi-ihb --save "$SMOKE_DIR/champ.json"
"$BIN" pipeline $SMOKE --method abm --save "$SMOKE_DIR/challenger.json"
SERVE_OUT=$("$BIN" serve $SMOKE \
  --model "m@v1=$SMOKE_DIR/champ.json,m@v2=$SMOKE_DIR/challenger.json" \
  --ab "m:v1=70,v2=30" --shadow "m:v2" --requests 300)
# print the human-readable summary, stop before the JSON document
echo "$SERVE_OUT" | sed -n '/^{/q;p'
# the RouterReport must account for every submitted request, and the
# demo path must actually serve them (totals count rejects too, so a
# fully-rejecting regression would otherwise still pass)
echo "$SERVE_OUT" | grep -q '^router.total_requests = 300$' || {
  echo "FAIL: RouterReport totals != requests submitted"
  echo "$SERVE_OUT"
  exit 1
}
echo "$SERVE_OUT" | grep -q '^router.total_rejected = 0$' || {
  echo "FAIL: serve smoke rejected requests"
  echo "$SERVE_OUT"
  exit 1
}
# compiled transform plans (ISSUE 10): every serving arm (v1 primary,
# v2 primary, v2 shadow) starts exactly one plan — plan_builds is 1 per
# arm, i.e. one build per distinct model behind each route, and never 0
# (a cold-rebuilding arm) or >1 (a plan rebuilt on the request path)
PLAN_ARMS=$(echo "$SERVE_OUT" | grep -c '"plan_builds": 1' || true)
if [[ "$PLAN_ARMS" -ne 3 ]]; then
  echo "FAIL: expected 3 serving arms with plan_builds=1, saw $PLAN_ARMS"
  echo "$SERVE_OUT"
  exit 1
fi
if echo "$SERVE_OUT" | grep -qE '"plan_builds": (0|[2-9])'; then
  echo "FAIL: an arm rebuilt (or never built) its transform plan"
  echo "$SERVE_OUT"
  exit 1
fi
# steady-state traffic must flow through the prepared plans
echo "$SERVE_OUT" | grep -qE '"plan_hits": [1-9]' || {
  echo "FAIL: no serving arm ever hit its compiled plan"
  echo "$SERVE_OUT"
  exit 1
}
echo "-- serve --shards deprecation warning"
SHARDS_WARN=$("$BIN" serve $SMOKE --requests 50 --shards 2 2>&1 >/dev/null)
echo "$SHARDS_WARN" | grep -qi "deprecated" || {
  echo "FAIL: serve --shards must print a deprecation warning"
  exit 1
}

echo "-- serve --listen: framed TCP front door + graceful shutdown (ISSUE 8 smoke)"
LISTEN_OUT="$SMOKE_DIR/listen.out"
"$BIN" serve $SMOKE --model "m@v1=$SMOKE_DIR/champ.json" \
  --listen 127.0.0.1:0 --read-timeout-ms 5000 > "$LISTEN_OUT" &
LISTEN_PID=$!
LISTEN_ADDR=""
for _ in $(seq 1 100); do
  LISTEN_ADDR=$(sed -n 's/^listening = //p' "$LISTEN_OUT" | head -n1)
  [[ -n "$LISTEN_ADDR" ]] && break
  sleep 0.1
done
if [[ -z "$LISTEN_ADDR" ]]; then
  echo "FAIL: serve --listen never printed its bound address"
  kill "$LISTEN_PID" 2>/dev/null || true
  exit 1
fi
# graceful shutdown from the shell: one 12-byte Shutdown frame (magic
# AVIW, version 1, kind 4, reserved, zero payload length) over /dev/tcp
LISTEN_PORT="${LISTEN_ADDR##*:}"
exec 3<>"/dev/tcp/127.0.0.1/$LISTEN_PORT"
printf 'AVIW\x01\x04\x00\x00\x00\x00\x00\x00' >&3
exec 3<&- 3>&-
if ! wait "$LISTEN_PID"; then
  echo "FAIL: serve --listen exited non-zero after a Shutdown frame"
  cat "$LISTEN_OUT"
  exit 1
fi
grep -q '"wire"' "$LISTEN_OUT" || {
  echo "FAIL: front-door RouterReport is missing the wire counter block"
  cat "$LISTEN_OUT"
  exit 1
}
grep -q '"connections": 1' "$LISTEN_OUT" || {
  echo "FAIL: front-door wire counters did not record the shutdown connection"
  cat "$LISTEN_OUT"
  exit 1
}

echo "-- model artifacts: pack -> push -> activate -> query, bitwise (ISSUE 9 smoke)"
"$BIN" model pack --model "$SMOKE_DIR/champ.json" --out "$SMOKE_DIR/champ.avib"
"$BIN" model inspect --model "$SMOKE_DIR/champ.avib" | grep -q '^codec    = binary (AVIB)' || {
  echo "FAIL: model pack did not produce a binary artifact"
  exit 1
}
# a server that loaded the JSON envelope at boot; the same model arrives
# a second time as a pushed binary artifact under a fresh key
ART_OUT="$SMOKE_DIR/artifact.out"
"$BIN" serve $SMOKE --model "m@v1=$SMOKE_DIR/champ.json" \
  --listen 127.0.0.1:0 --read-timeout-ms 5000 \
  --artifact-dir "$SMOKE_DIR/store" > "$ART_OUT" &
ART_PID=$!
ART_ADDR=""
for _ in $(seq 1 100); do
  ART_ADDR=$(sed -n 's/^listening = //p' "$ART_OUT" | head -n1)
  [[ -n "$ART_ADDR" ]] && break
  sleep 0.1
done
if [[ -z "$ART_ADDR" ]]; then
  echo "FAIL: artifact smoke server never printed its bound address"
  kill "$ART_PID" 2>/dev/null || true
  exit 1
fi
"$BIN" model push --addr "$ART_ADDR" --key m2 --version v1 --model "$SMOKE_DIR/champ.avib"
"$BIN" model activate --addr "$ART_ADDR" --key m2 --version v1
# identical model behind both routes ⇒ the {:?}-formatted score lines
# must match bit for bit (JSON-loaded vs binary-pushed serving path)
ART_ROW="0.31,0.67,0.52"
Q_JSON=$("$BIN" model query --addr "$ART_ADDR" --route m --row "$ART_ROW" | grep '^scores')
Q_BIN=$("$BIN" model query --addr "$ART_ADDR" --route m2 --row "$ART_ROW" | grep '^scores')
if [[ -z "$Q_JSON" || "$Q_JSON" != "$Q_BIN" ]]; then
  echo "FAIL: binary-pushed route diverged from the JSON-loaded route:"
  echo "  json: $Q_JSON"
  echo "  bin:  $Q_BIN"
  kill "$ART_PID" 2>/dev/null || true
  exit 1
fi
# a pull must return the exact pushed bytes (checksummed at both ends)
"$BIN" model pull --addr "$ART_ADDR" --key m2 --out "$SMOKE_DIR/pulled.avib"
cmp -s "$SMOKE_DIR/champ.avib" "$SMOKE_DIR/pulled.avib" || {
  echo "FAIL: pulled artifact differs from the pushed bytes"
  kill "$ART_PID" 2>/dev/null || true
  exit 1
}
ART_PORT="${ART_ADDR##*:}"
exec 3<>"/dev/tcp/127.0.0.1/$ART_PORT"
printf 'AVIW\x01\x04\x00\x00\x00\x00\x00\x00' >&3
exec 3<&- 3>&-
if ! wait "$ART_PID"; then
  echo "FAIL: artifact smoke server exited non-zero after a Shutdown frame"
  cat "$ART_OUT"
  exit 1
fi
grep -q '"model_pushes": 1' "$ART_OUT" || {
  echo "FAIL: wire counters did not record the model push"
  cat "$ART_OUT"
  exit 1
}
grep -q '"model_activations": 1' "$ART_OUT" || {
  echo "FAIL: wire counters did not record the activation"
  cat "$ART_OUT"
  exit 1
}

echo "-- dataset plane: ingest -> inspect -> stats -> split -> fit --store mmap (ISSUE 7 smoke)"
DATA_CSV="$SMOKE_DIR/toy.csv"
{
  echo "f0,f1,f2,label"
  awk 'BEGIN { for (i = 0; i < 900; i++)
    printf "%.6f,%.6f,%.6f,%d\n", i/900.0, ((i*i)%97)/97.0, 1-i/1800.0, i%2 }'
} > "$DATA_CSV"
"$BIN" dataset ingest --csv "$DATA_CSV" --out "$SMOKE_DIR/ds" --name toy --rows-per-shard 128
"$BIN" dataset inspect --data "$SMOKE_DIR/ds" | grep -q '^verify   = ok' || {
  echo "FAIL: dataset inspect did not verify the ingested segments"
  exit 1
}
# a 1 MiB budget forces the resident pool to work, and stats must agree
# regardless (shard-outer streaming scan)
"$BIN" dataset stats --data "$SMOKE_DIR/ds" --mem-budget-mb 1 | grep -q '^store    = ' || {
  echo "FAIL: dataset stats did not report backing counters for a spill store"
  exit 1
}
"$BIN" dataset split --data "$SMOKE_DIR/ds" --out-train "$SMOKE_DIR/ds-tr" \
  --out-test "$SMOKE_DIR/ds-te" --test-frac 0.3 --seed 7
"$BIN" dataset inspect --data "$SMOKE_DIR/ds-tr" >/dev/null
"$BIN" dataset inspect --data "$SMOKE_DIR/ds-te" >/dev/null
# fit from the ingested directory, in-memory vs spill-backed: the exact
# path is a bitwise contract, so the model-shape lines must be identical
MEM_FIT=$("$BIN" fit --data "$SMOKE_DIR/ds" --psi 0.01 --method cgavi-ihb)
MMAP_FIT=$("$BIN" fit --data "$SMOKE_DIR/ds" --psi 0.01 --method cgavi-ihb \
  --store mmap --mem-budget-mb 1)
echo "$MMAP_FIT"
echo "$MEM_FIT" | grep -q '"store":"mem"' || {
  echo "FAIL: default fit no longer reports store=mem"
  exit 1
}
echo "$MMAP_FIT" | grep -q '"store":"mmap"' || {
  echo "FAIL: --store mmap fit did not report store=mmap"
  exit 1
}
MEM_SHAPE=$(echo "$MEM_FIT" | grep -E '^\|G\||^avg deg|^SPAR')
MMAP_SHAPE=$(echo "$MMAP_FIT" | grep -E '^\|G\||^avg deg|^SPAR')
if [[ "$MEM_SHAPE" != "$MMAP_SHAPE" ]]; then
  echo "FAIL: spill-backed fit diverged from the in-memory fit:"
  diff <(echo "$MEM_SHAPE") <(echo "$MMAP_SHAPE") || true
  exit 1
fi
rm -rf "$SMOKE_DIR"

echo "verify.sh: all gates passed"

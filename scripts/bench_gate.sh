#!/usr/bin/env bash
# Perf trajectory gate (run from the repo root):
#
#   scripts/bench_gate.sh            # run the micro benches, then gate
#   SKIP_RUN=1 scripts/bench_gate.sh # gate existing artifacts only
#   TOLERANCE=25 scripts/bench_gate.sh
#
# The micro benches emit flat machine-readable artifacts
# (rust/target/bench_results/BENCH_<id>.json, written by
# `bench::BenchJson` as one `"key": value` pair per line).  This gate
# diffs every `_ns` timing cell against the committed baseline under
# bench/ and fails if any cell regressed by more than TOLERANCE percent
# (default 15, the ISSUE 6 bar).  Non-timing cells (counters, error
# budgets, speedups) are trajectory data, not gated.
#
# On pass, the fresh artifacts are copied over the baselines so the
# committed trajectory advances with the commit that earned it.  A
# missing baseline installs rather than fails (first run on a new
# bench).  No jq in the container — sed/awk only.
set -euo pipefail
cd "$(dirname "$0")/.."

BASELINE_DIR=bench
FRESH_DIR=rust/target/bench_results
TOLERANCE=${TOLERANCE:-15}
BENCHES=(micro_gram_panel backend_scaling serve_router serve_transform persist_codec)

if [[ "${SKIP_RUN:-0}" != "1" ]]; then
  echo "== running micro benches =="
  (cd rust && cargo bench --bench micro_gram_panel && cargo bench --bench micro_backend_scaling \
    && cargo bench --bench serve_router && cargo bench --bench serve_transform \
    && cargo bench --bench micro_persist_codec)
fi

mkdir -p "$BASELINE_DIR"

# print "key value" lines for every numeric _ns cell of a BenchJson file
ns_cells() {
  sed -n 's/^[[:space:]]*"\([A-Za-z0-9_]*_ns\)":[[:space:]]*\([0-9][0-9.eE+-]*\),\{0,1\}$/\1 \2/p' "$1"
}

fail=0
for id in "${BENCHES[@]}"; do
  fresh="$FRESH_DIR/BENCH_$id.json"
  base="$BASELINE_DIR/BENCH_$id.json"
  if [[ ! -f "$fresh" ]]; then
    echo "FAIL: $fresh missing — did the bench run and call BenchJson::write()?"
    exit 1
  fi
  if [[ ! -f "$base" ]]; then
    echo "== $id: no baseline, installing $base =="
    cp "$fresh" "$base"
    continue
  fi
  echo "== $id: diffing against $base (tolerance ${TOLERANCE}%) =="
  # join baseline and fresh cells on key; gate only keys present in both
  # so bench additions/removals never fail the gate by themselves
  verdicts=$(
    { ns_cells "$base" | sed 's/^/B /'; ns_cells "$fresh" | sed 's/^/F /'; } |
      awk -v tol="$TOLERANCE" '
        $1 == "B" { base[$2] = $3 }
        $1 == "F" { fresh[$2] = $3 }
        END {
          for (k in fresh) {
            if (!(k in base) || base[k] <= 0) {
              # new timing cell with no committed baseline: visible but
              # not gated (it installs on the pass-time baseline copy)
              printf "%-40s %14s -> %14.0f  %7s  WARN: no baseline (skipped)\n", k, "-", fresh[k], "-"
              continue
            }
            delta = (fresh[k] - base[k]) * 100.0 / base[k]
            status = delta > tol ? "REGRESSED" : "ok"
            printf "%-40s %14.0f -> %14.0f  %+7.1f%%  %s\n", k, base[k], fresh[k], delta, status
          }
        }' | sort
  )
  echo "$verdicts"
  if echo "$verdicts" | grep -q 'REGRESSED$'; then
    echo "FAIL: $id has timing cells regressed beyond ${TOLERANCE}%"
    fail=1
  fi
done

if [[ "$fail" != "0" ]]; then
  echo "bench_gate.sh: regression detected — baselines left untouched"
  exit 1
fi

# advance the committed trajectory
for id in "${BENCHES[@]}"; do
  cp "$FRESH_DIR/BENCH_$id.json" "$BASELINE_DIR/BENCH_$id.json"
done
echo "bench_gate.sh: all timing cells within ${TOLERANCE}% — baselines updated under $BASELINE_DIR/"
